//! Deterministic pseudo-random number generation (PCG64-DXSM) plus the
//! distributions the data generators need: uniform, normal (Box–Muller),
//! Bernoulli, binomial, and sampling without replacement.
//!
//! `rand` is unavailable offline; this is a small, well-tested substitute.
//! Determinism matters here: every experiment in `EXPERIMENTS.md` records its
//! seed, and the synthetic datasets of the paper's §5.1 are regenerated
//! bit-identically from (kind, p, q, n, seed).

/// PCG64-DXSM generator (O'Neill). 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: (seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15_f39c_c060_5ced_c834),
            inc: ((seed as u128) << 1) | 1,
            spare_normal: None,
        };
        // Warm up to decorrelate small seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent stream (for per-thread / per-column use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let s = self.next_u64() ^ stream.wrapping_mul(0xa076_1d64_78bd_642f);
        Rng::new(s)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // PCG64-DXSM output function.
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough method (bias < 2^-64·n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * m);
                return u * m;
            }
        }
    }

    /// Bernoulli(prob).
    #[inline]
    pub fn bernoulli(&mut self, prob: f64) -> bool {
        self.uniform() < prob
    }

    /// Binomial(n, prob) by direct summation (n is small in our use: 2).
    pub fn binomial(&mut self, n: usize, prob: f64) -> usize {
        (0..n).filter(|_| self.bernoulli(prob)).count()
    }

    /// k distinct indices sampled uniformly from [0, n), Floyd's algorithm.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        let m = s1 / n as f64;
        let var = s2 / n as f64 - m * m;
        let skew = s3 / n as f64;
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.03, "skew={skew}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let k = 1 + rng.below(20);
            let n = k + rng.below(50);
            let mut s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            assert!(s.iter().all(|&i| i < n));
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "duplicates produced");
        }
    }

    #[test]
    fn binomial_in_range() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let b = rng.binomial(2, 0.3);
            assert!(b <= 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut w = v.clone();
        w.sort_unstable();
        assert_eq!(w, (0..50).collect::<Vec<_>>());
    }
}
