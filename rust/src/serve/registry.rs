//! Named, long-lived warm solver contexts with budget-driven LRU eviction.
//!
//! A serving process answers many jobs against few datasets. The expensive
//! per-dataset state — the raw arrays, the `S_yy`/`S_xx`/`S_xy` Gram
//! statistics, the block solver's clustering partitions, the colored CD
//! sweeps' conflict colorings, and the most recent fitted model per solver
//! (the warm-start seed) — all lives in or next to a [`SolverContext`], so
//! keeping *that* alive between jobs is what makes a repeat `fit` cost an
//! optimization instead of an optimization plus a data pipeline.
//!
//! [`Registry`] owns those contexts by name. Every byte an entry pins —
//! raw dataset, materialized statistics, cached models — registers against
//! one shared [`MemBudget`] (the same budget running jobs draw their
//! working sets from, so `peak()` covers the whole process and the cap is
//! a real cap). When a load does not fit, idle least-recently-used entries
//! are evicted until it does ([`Registry::ensure_room`]); an entry a job
//! is still using is never evicted (liveness is the entry `Arc`'s strong
//! count, read under the registry lock that all clones are created under).
//!
//! # Safety of [`WarmContext`]
//!
//! `SolverContext<'a>` borrows its dataset and engine; a registry entry
//! must *own* them. `WarmContext` bundles the context with the `Arc`s it
//! borrows from, erasing the borrow lifetime to `'static`. This is sound
//! because (a) `Arc` heap addresses are stable and both `Arc`s live in the
//! same struct as the context, (b) the context field is declared first so
//! it drops before them, (c) nothing hands out `&mut Dataset`, and (d) the
//! only context accessor re-shortens the erased lifetime to the borrow of
//! `self` (`SolverContext` is covariant in its lifetime), so the `'static`
//! can never leak to a caller.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cggm::{CggmModel, Dataset, WindowDelta};
use crate::gemm::GemmEngine;
use crate::solvers::{SolveOptions, SolverContext, SolverKind};
use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};

/// One dataset's warm state: the solver context plus the warm-start model
/// cache. Jobs take the entry's mutex for the duration of a solve
/// (`SolverContext` is single-threaded by design; two jobs on the *same*
/// dataset serialize, jobs on different datasets run concurrently).
pub struct WarmContext {
    /// Declared first: drops before the `Arc`s it borrows from.
    ctx: SolverContext<'static>,
    /// Most recent fitted model per solver, budget-tracked.
    models: HashMap<&'static str, CachedModel>,
    /// Rows accepted by `append` but not yet folded into the window by a
    /// `refit` (each row a `(x, y)` pair of length `p` / `q`).
    pending: Vec<(Vec<f64>, Vec<f64>)>,
    /// Budget registrations covering `pending` (one per accepted `append`).
    pending_tracks: Vec<Tracked>,
    /// Lifetime samples folded into / expired out of the window by refits.
    appended: usize,
    evicted: usize,
    /// Registration of the raw dataset bytes against the shared budget.
    _data_track: Tracked,
    data: Arc<Dataset>,
    engine: Arc<dyn GemmEngine>,
}

struct CachedModel {
    model: CggmModel,
    lam: (f64, f64),
    bytes: usize,
    _track: Tracked,
}

impl WarmContext {
    /// Build a warm context owning `data`. Fails (without allocating) when
    /// the shared budget cannot hold the raw dataset bytes.
    pub fn new(
        data: Arc<Dataset>,
        engine: Arc<dyn GemmEngine>,
        opts: &SolveOptions,
    ) -> Result<WarmContext, BudgetExceeded> {
        let data_track = opts.budget.track(data.bytes())?;
        // SAFETY: see the module docs — the referents live behind `Arc`s
        // owned by this struct (stable addresses), `ctx` drops first, and
        // `Self::ctx` re-shortens the lifetime on every access.
        let data_ref: &'static Dataset = unsafe { &*Arc::as_ptr(&data) };
        let engine_ref: &'static dyn GemmEngine = unsafe { &*Arc::as_ptr(&engine) };
        let ctx = SolverContext::new(data_ref, opts, engine_ref);
        Ok(WarmContext {
            ctx,
            models: HashMap::new(),
            pending: Vec::new(),
            pending_tracks: Vec::new(),
            appended: 0,
            evicted: 0,
            _data_track: data_track,
            data,
            engine,
        })
    }

    /// Replace the owned dataset with `data` (the slid window) while
    /// carrying every cache the old context held — materialized Gram
    /// blocks, resident tiles, clustering partitions, CD colorings — and
    /// correcting the carried statistics in place with the rank-k update
    /// described by `delta`. This is the streaming re-fit path: statistics
    /// cost scales with `delta`, not with the window size.
    ///
    /// The new dataset must keep the old one's `(p, q)` shape (the window
    /// slides over samples, never over features). Fails only when the
    /// shared budget cannot hold the new raw dataset bytes, leaving `self`
    /// untouched; a budget failure *inside* the statistics correction
    /// instead degrades to invalidation (lazy recompute), never an error.
    pub fn rebuild(
        &mut self,
        data: Arc<Dataset>,
        delta: &WindowDelta,
        opts: &SolveOptions,
    ) -> Result<(), BudgetExceeded> {
        assert_eq!(
            (data.p(), data.q()),
            (self.data.p(), self.data.q()),
            "window rebuild must preserve feature dimensions"
        );
        // Reserve the new dataset's bytes first so failure changes nothing.
        // Old and new windows briefly double-count; the engine's admission
        // estimate covers the overlap.
        let data_track = opts.budget.track(data.bytes())?;
        // SAFETY: same argument as `new` — the new referent lives behind an
        // `Arc` stored in this struct below (stable address), and `ctx`
        // drops before it. Between the swap and the `self.data` store the
        // new `Arc` is alive in this frame, and no code in between panics
        // while holding a context over it.
        let data_ref: &'static Dataset = unsafe { &*Arc::as_ptr(&data) };
        let engine_ref: &'static dyn GemmEngine = unsafe { &*Arc::as_ptr(&self.engine) };
        // Swap a bare context over the new data in, take the old one out,
        // and strip it for parts: `into_carry` releases the old context's
        // budget registrations and returns its caches as plain matrices.
        let fresh = SolverContext::new(data_ref, opts, engine_ref);
        let carry = std::mem::replace(&mut self.ctx, fresh).into_carry();
        self.ctx = SolverContext::with_carry(data_ref, opts, engine_ref, carry);
        if self.ctx.update_stats(delta).is_err() {
            // The correction scratch did not fit: drop the carried stats
            // and recompute lazily. Slower, never wrong.
            self.ctx.invalidate_stats();
        }
        self.data = data;
        self._data_track = data_track;
        self.appended += delta.added_k();
        self.evicted += delta.removed_k();
        Ok(())
    }

    /// Buffer `rows` for the next refit. Shape validation happens at the
    /// engine layer (it has the structured-error machinery); here the rows
    /// only need to fit the budget. Returns the buffered-row total.
    pub fn push_pending(
        &mut self,
        rows: Vec<(Vec<f64>, Vec<f64>)>,
        budget: &MemBudget,
    ) -> Result<usize, BudgetExceeded> {
        let bytes: usize = rows.iter().map(|(x, y)| 8 * (x.len() + y.len())).sum();
        let track = budget.track(bytes)?;
        self.pending_tracks.push(track);
        self.pending.extend(rows);
        Ok(self.pending.len())
    }

    /// Take every buffered row (oldest first), releasing their budget
    /// registration — the refit job re-accounts them inside the new window.
    pub fn take_pending(&mut self) -> Vec<(Vec<f64>, Vec<f64>)> {
        self.pending_tracks.clear();
        std::mem::take(&mut self.pending)
    }

    /// Rows buffered by `append` and not yet folded in by a `refit`.
    pub fn pending_rows(&self) -> usize {
        self.pending.len()
    }

    fn pending_bytes(&self) -> usize {
        self.pending.iter().map(|(x, y)| 8 * (x.len() + y.len())).sum()
    }

    /// Lifetime samples folded into the window by refits.
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// Lifetime samples expired out of the window by refits.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    /// Cached statistics corrected in place by incremental window updates
    /// (vs. `stat_computes`, which counts from-scratch materializations).
    pub fn stat_updates(&self) -> usize {
        self.ctx.stat_updates()
    }

    /// The warm solver context, with the erased `'static` shortened back to
    /// this borrow (covariance) so it cannot outlive the entry.
    pub fn ctx<'s>(&'s self) -> &'s SolverContext<'s> {
        &self.ctx
    }

    /// Shared handle to the raw dataset (CV jobs fold-split it without
    /// holding the entry lock).
    pub fn data(&self) -> Arc<Dataset> {
        self.data.clone()
    }

    /// Shared handle to the GEMM engine.
    pub fn engine(&self) -> Arc<dyn GemmEngine> {
        self.engine.clone()
    }

    /// Eagerly materialize the dense statistics (`load`'s warm mode): every
    /// later job on this entry starts with the Gram work already paid.
    pub fn warm_stats(&self) -> Result<(), BudgetExceeded> {
        self.ctx.syy()?;
        self.ctx.sxy()?;
        self.ctx.sxx()?;
        Ok(())
    }

    /// Dense statistics materialized so far (the registry-hit observability
    /// counter: a warm repeat job leaves this unchanged).
    pub fn stat_computes(&self) -> usize {
        self.ctx.stat_computes()
    }

    /// Tile-cache counters of the context's `StatMode::Tiled` statistics
    /// layer (`None` in dense mode or before the first tiled read). Like
    /// `stat_computes`, cumulative over the entry's lifetime.
    pub fn tile_stats(&self) -> Option<crate::cggm::tiles::TileStats> {
        self.ctx.tile_stats()
    }

    /// The warm-start seed for `kind`, if a model was cached.
    pub fn cached_model(&self, kind: SolverKind) -> Option<&CggmModel> {
        self.models.get(kind.name()).map(|c| &c.model)
    }

    /// The λ the cached model for `kind` was fitted at.
    pub fn cached_lambda(&self, kind: SolverKind) -> Option<(f64, f64)> {
        self.models.get(kind.name()).map(|c| c.lam)
    }

    /// Solver names with a cached warm-start model, sorted (stable `stat`
    /// output — also what `save`/`export` can serialize).
    pub fn cached_solvers(&self) -> Vec<&'static str> {
        let mut names: Vec<&'static str> = self.models.keys().copied().collect();
        names.sort_unstable();
        names
    }

    /// Cache `model` as the warm-start seed for `kind`, replacing any
    /// previous one. Returns `false` (and caches nothing) when the budget
    /// cannot hold it — serving degrades to cold starts, never errors.
    pub fn store_model(
        &mut self,
        kind: SolverKind,
        model: CggmModel,
        lam: (f64, f64),
        budget: &MemBudget,
    ) -> bool {
        // Release the previous model's bytes before asking for the new
        // one's, so replacement never double-counts.
        self.models.remove(kind.name());
        let bytes = model.bytes();
        match budget.track(bytes) {
            Ok(track) => {
                self.models.insert(
                    kind.name(),
                    CachedModel {
                        model,
                        lam,
                        bytes,
                        _track: track,
                    },
                );
                true
            }
            Err(_) => false,
        }
    }

    /// Bytes this entry pins in the shared budget while idle: raw data,
    /// materialized statistics, cached models, pending appended rows.
    pub fn pinned_bytes(&self) -> usize {
        self.data.bytes()
            + self.ctx.cached_stat_bytes()
            + self.models.values().map(|c| c.bytes).sum::<usize>()
            + self.pending_bytes()
    }
}

/// Registry errors, surfaced as structured serve responses.
#[derive(Debug, thiserror::Error)]
pub enum RegistryError {
    #[error("dataset '{0}' is not loaded")]
    NotFound(String),
    #[error("dataset '{0}' is in use by a running job")]
    Busy(String),
    #[error("registry budget cannot hold the dataset: {0}")]
    Budget(#[from] BudgetExceeded),
}

/// Per-entry bookkeeping snapshot (counters updated after each job so
/// `stat` never has to wait behind a running solve for the entry lock).
pub struct Entry {
    pub warm: Arc<Mutex<WarmContext>>,
    pub p: usize,
    pub q: usize,
    pub n: usize,
    /// Logical LRU clock value of the last lookup.
    pub last_used: u64,
    /// Jobs executed against this entry.
    pub jobs: usize,
    /// Jobs that were seeded from the cached model.
    pub warm_reuses: usize,
    /// Snapshot of the context's statistic-compute counter.
    pub stat_computes: usize,
    /// Snapshot of the context's in-place statistic-correction counter.
    pub stat_updates: usize,
    /// Lifetime samples folded into / expired out of the sliding window.
    pub appended: usize,
    pub evicted: usize,
    /// Rows buffered by `append` awaiting the next `refit`.
    pub pending: usize,
    /// Snapshot of the tile cache's counters (`None` until the entry's
    /// context serves a tiled read; always `None` in dense mode).
    pub tile_stats: Option<crate::cggm::tiles::TileStats>,
    /// Storage mode of the owned dataset (`"mem"` or `"disk"`; fixed at
    /// load — a window never changes backing).
    pub storage: &'static str,
    /// Snapshot of the panel-cache counters (`None` for resident entries).
    pub panel_stats: Option<crate::storage::PanelStats>,
    /// Snapshot of the bytes the entry pins.
    pub pinned_bytes: usize,
}

/// Named warm contexts sharing one [`MemBudget`], LRU-evicted under
/// pressure.
pub struct Registry {
    entries: HashMap<String, Entry>,
    budget: MemBudget,
    clock: u64,
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
}

impl Registry {
    pub fn new(budget: MemBudget) -> Registry {
        Registry {
            entries: HashMap::new(),
            budget,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn budget(&self) -> &MemBudget {
        &self.budget
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Iterate entries for `stat` reporting (no LRU effect).
    pub fn entries(&self) -> impl Iterator<Item = (&String, &Entry)> {
        self.entries.iter()
    }

    /// Read an entry without touching LRU/hit accounting (admission
    /// estimation).
    pub fn peek(&self, name: &str) -> Option<&Entry> {
        self.entries.get(name)
    }

    /// Look up an entry for a job: bumps the LRU clock and the hit/miss
    /// counters, returns a clone of the entry handle (the caller locks it
    /// outside the registry lock).
    pub fn lookup(&mut self, name: &str) -> Option<Arc<Mutex<WarmContext>>> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(name) {
            Some(e) => {
                e.last_used = clock;
                self.hits += 1;
                Some(e.warm.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Update an entry's post-job counter snapshots.
    pub fn refresh(&mut self, name: &str, f: impl FnOnce(&mut Entry)) {
        if let Some(e) = self.entries.get_mut(name) {
            f(e);
        }
    }

    /// Register a freshly built warm context under `name`. The caller
    /// builds the (possibly expensive) context *outside* the registry lock;
    /// this just installs it. Re-loading an existing name is rejected as
    /// [`RegistryError::Busy`]-free idempotence at the engine layer — here
    /// it replaces only if idle, so a stale entry cannot shadow new data.
    pub fn insert(&mut self, name: &str, warm: WarmContext) -> Result<(), RegistryError> {
        if let Some(e) = self.entries.get(name) {
            if Arc::strong_count(&e.warm) > 1 {
                return Err(RegistryError::Busy(name.to_string()));
            }
            self.evictions += 1;
        }
        self.clock += 1;
        let data = warm.data();
        let entry = Entry {
            p: data.p(),
            q: data.q(),
            n: data.n(),
            last_used: self.clock,
            jobs: 0,
            warm_reuses: 0,
            stat_computes: warm.stat_computes(),
            stat_updates: warm.stat_updates(),
            appended: warm.appended(),
            evicted: warm.evicted(),
            pending: warm.pending_rows(),
            tile_stats: warm.tile_stats(),
            storage: data.storage_name(),
            panel_stats: data.panel_stats(),
            pinned_bytes: warm.pinned_bytes(),
            warm: Arc::new(Mutex::new(warm)),
        };
        self.entries.insert(name.to_string(), entry);
        Ok(())
    }

    /// Drop `name`, freeing every byte it pinned. Refuses while a job holds
    /// the entry.
    pub fn evict(&mut self, name: &str) -> Result<usize, RegistryError> {
        match self.entries.get(name) {
            None => Err(RegistryError::NotFound(name.to_string())),
            Some(e) if Arc::strong_count(&e.warm) > 1 => {
                Err(RegistryError::Busy(name.to_string()))
            }
            Some(_) => {
                let before = self.budget.live();
                self.entries.remove(name);
                self.evictions += 1;
                Ok(before.saturating_sub(self.budget.live()))
            }
        }
    }

    /// Evict idle entries, least-recently-used first (never `keep`), until
    /// `need` bytes fit in the shared budget. Returns whether they now do.
    pub fn ensure_room(&mut self, need: usize, keep: Option<&str>) -> bool {
        while self.budget.available() < need {
            let victim = self
                .entries
                .iter()
                .filter(|(name, e)| {
                    Some(name.as_str()) != keep && Arc::strong_count(&e.warm) == 1
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    self.entries.remove(&name);
                    self.evictions += 1;
                }
                None => return false,
            }
        }
        true
    }

    /// Total bytes pinned by idle registry state (entry snapshots).
    pub fn pinned_bytes(&self) -> usize {
        self.entries.values().map(|e| e.pinned_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::linalg::dense::Mat;
    use crate::solvers::solve_in_context;
    use crate::util::rng::Rng;

    fn small_data(seed: u64, n: usize, p: usize, q: usize) -> Arc<Dataset> {
        let mut rng = Rng::new(seed);
        Arc::new(Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        ))
    }

    fn opts_with(budget: &MemBudget) -> SolveOptions {
        SolveOptions {
            budget: budget.clone(),
            ..Default::default()
        }
    }

    #[test]
    fn warm_context_pins_data_stats_and_models() {
        let budget = MemBudget::unlimited();
        let eng: Arc<dyn GemmEngine> = Arc::new(NativeGemm::new(1));
        let data = small_data(1, 20, 4, 5);
        let data_bytes = data.bytes();
        let warm = WarmContext::new(data, eng, &opts_with(&budget)).unwrap();
        assert_eq!(budget.live(), data_bytes);
        warm.warm_stats().unwrap();
        let stats = 8 * (5 * 5 + 4 * 4 + 4 * 5);
        assert_eq!(budget.live(), data_bytes + stats);
        assert_eq!(warm.pinned_bytes(), budget.live());
        assert_eq!(warm.stat_computes(), 3);
        // A repeat warm is free.
        warm.warm_stats().unwrap();
        assert_eq!(warm.stat_computes(), 3);
        drop(warm);
        assert_eq!(budget.live(), 0, "eviction must free every byte");
    }

    #[test]
    fn warm_context_solves_and_caches_models() {
        let budget = MemBudget::unlimited();
        let eng: Arc<dyn GemmEngine> = Arc::new(NativeGemm::new(1));
        let mut warm =
            WarmContext::new(small_data(2, 60, 8, 8), eng, &opts_with(&budget)).unwrap();
        let opts = SolveOptions {
            lam_l: 0.4,
            lam_t: 0.4,
            max_iter: 40,
            budget: budget.clone(),
            ..Default::default()
        };
        let kind = SolverKind::AltNewtonCd;
        assert!(warm.cached_model(kind).is_none());
        let cold = solve_in_context(kind, warm.ctx(), &opts, None).unwrap();
        assert!(!cold.trace.warm_started);
        assert!(warm.store_model(kind, cold.model.clone(), (0.4, 0.4), &budget));
        assert_eq!(warm.cached_lambda(kind), Some((0.4, 0.4)));
        // Second solve: seeded, zero statistic recomputation, same optimum.
        let before = warm.stat_computes();
        let rewarm =
            solve_in_context(kind, warm.ctx(), &opts, warm.cached_model(kind)).unwrap();
        assert!(rewarm.trace.warm_started);
        assert_eq!(warm.stat_computes(), before);
        let (a, b) = (
            cold.trace.final_f().unwrap(),
            rewarm.trace.final_f().unwrap(),
        );
        assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0));
        // Replacing the cached model releases the old bytes first.
        let live = budget.live();
        assert!(warm.store_model(kind, rewarm.model, (0.4, 0.4), &budget));
        assert!(
            budget.live() <= live + 1024,
            "replacement must not accumulate"
        );
    }

    #[test]
    fn registry_lru_eviction_frees_bytes_and_skips_busy() {
        let eng: Arc<dyn GemmEngine> = Arc::new(NativeGemm::new(1));
        let budget = MemBudget::new(64 << 10);
        let opts = opts_with(&budget);
        let mut reg = Registry::new(budget.clone());
        // Each dataset: 8·n·(p+q) = 8·40·20 = 6.4KB + warm stats ~2.6KB.
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let warm =
                WarmContext::new(small_data(10 + i as u64, 40, 10, 10), eng.clone(), &opts)
                    .unwrap();
            warm.warm_stats().unwrap();
            reg.insert(name, warm).unwrap();
        }
        assert_eq!(reg.len(), 3);
        let live = budget.live();
        assert!(live > 0);
        // Touch "a" so "b" is the LRU victim.
        assert!(reg.lookup("a").is_some());
        assert!(reg.lookup("missing").is_none());
        assert_eq!((reg.hits, reg.misses), (1, 1));
        // Demand almost the whole budget: evicts b then c, keeps a.
        assert!(reg.ensure_room(budget.limit() - reg.peek("a").unwrap().pinned_bytes, None));
        assert_eq!(reg.len(), 1);
        assert!(reg.contains("a"));
        assert_eq!(reg.evictions, 2);
        assert!(budget.live() < live);
        // A held entry is never evicted: demand more than can ever fit.
        let held = reg.lookup("a").unwrap();
        assert!(!reg.ensure_room(budget.limit() + 1, None));
        assert!(reg.contains("a"));
        assert!(matches!(reg.evict("a"), Err(RegistryError::Busy(_))));
        drop(held);
        let freed = reg.evict("a").unwrap();
        assert!(freed > 0);
        assert_eq!(budget.live(), 0);
        assert!(matches!(reg.evict("a"), Err(RegistryError::NotFound(_))));
    }

    #[test]
    fn rebuild_slides_the_window_on_carried_stats() {
        use crate::cggm::SampleBlock;
        let budget = MemBudget::unlimited();
        let eng: Arc<dyn GemmEngine> = Arc::new(NativeGemm::new(1));
        let opts = opts_with(&budget);
        let data = small_data(7, 30, 4, 5);
        let mut warm = WarmContext::new(data.clone(), eng, &opts).unwrap();
        warm.warm_stats().unwrap();
        assert_eq!(warm.stat_computes(), 3);
        // Buffer two rows; the buffer pins budget until taken.
        let mut rng = Rng::new(99);
        let mut row = |len: usize| (0..len).map(|_| rng.normal()).collect::<Vec<f64>>();
        let rows = vec![(row(4), row(5)), (row(4), row(5))];
        let live_before = budget.live();
        assert_eq!(warm.push_pending(rows, &budget).unwrap(), 2);
        assert_eq!(warm.pending_rows(), 2);
        assert_eq!(budget.live(), live_before + 8 * 2 * 9);
        assert_eq!(warm.pinned_bytes(), budget.live());
        let rows = warm.take_pending();
        assert_eq!(warm.pending_rows(), 0);
        assert_eq!(budget.live(), live_before);
        // Slide the window exactly as the refit job does: append the taken
        // rows, expire the oldest two, rebuild over the new dataset.
        let mut next = (*data).clone();
        let mut delta = WindowDelta::new(next.n());
        let xa = Mat::from_fn(4, 2, |i, j| rows[j].0[i]);
        let ya = Mat::from_fn(5, 2, |i, j| rows[j].1[i]);
        next.append_samples(&xa, &ya).unwrap();
        delta.record_append(SampleBlock::new(xa, ya));
        delta.record_evict(next.evict_oldest(2).unwrap());
        let next = Arc::new(next);
        warm.rebuild(next.clone(), &delta, &opts).unwrap();
        assert_eq!((warm.appended(), warm.evicted()), (2, 2));
        assert_eq!(warm.stat_computes(), 3, "carried stats must not recompute");
        assert!(warm.stat_updates() >= 3, "dense blocks corrected in place");
        // Corrected statistics match a from-scratch context over the slid
        // window.
        let eng2 = NativeGemm::new(1);
        let fresh = SolverContext::new(&next, &opts, &eng2);
        for (got, want) in [
            (warm.ctx().syy().unwrap(), fresh.syy().unwrap()),
            (warm.ctx().sxx().unwrap(), fresh.sxx().unwrap()),
            (warm.ctx().sxy().unwrap(), fresh.sxy().unwrap()),
        ] {
            assert!(got.max_abs_diff(want) <= 1e-10);
        }
        drop(fresh);
        drop(warm);
        assert_eq!(budget.live(), 0, "rebuild must not leak budget");
    }

    #[test]
    fn oversized_dataset_fails_fast_without_allocating() {
        let budget = MemBudget::new(1024);
        let eng: Arc<dyn GemmEngine> = Arc::new(NativeGemm::new(1));
        // 8·40·20 = 6.4KB of raw data > 1KB budget.
        let err = WarmContext::new(small_data(3, 40, 10, 10), eng, &opts_with(&budget));
        assert!(err.is_err());
        assert_eq!(budget.live(), 0);
    }
}
