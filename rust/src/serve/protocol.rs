//! JSONL wire protocol of `cggm serve` / `cggm batch`.
//!
//! One JSON object per line, in both directions. Requests:
//!
//! ```text
//! {"op":"load","id":1,"name":"expr","path":"expr.bin"}
//! {"op":"load","id":2,"name":"syn","workload":"chain","p":200,"q":200,"n":100,"seed":7}
//! {"op":"load","id":15,"name":"big","path":"big.pan","storage":"disk"}
//! {"op":"fit","id":3,"dataset":"syn","solver":"alt","lambda":0.4,"tol":0.001}
//! {"op":"path","id":4,"dataset":"syn","solver":"alt","path_points":8,"stream":true}
//! {"op":"cv","id":5,"dataset":"syn","cv_folds":5,"cv_threads":2}
//! {"op":"append","id":12,"dataset":"syn","rows":[{"x":[...],"y":[...]}]}
//! {"op":"append","id":13,"dataset":"syn","path":"more.bin"}
//! {"op":"refit","id":14,"dataset":"syn","window":100,"lambda":0.4}
//! {"op":"stat","id":6}
//! {"op":"evict","id":7,"dataset":"expr"}
//! {"op":"cancel","id":8,"job":4}
//! {"op":"save","id":9,"dataset":"syn","path":"syn.model.jsonl","solver":"alt"}
//! {"op":"export","id":10,"dataset":"syn","solver":"alt"}
//! {"op":"shutdown","id":11}
//! ```
//!
//! `append` buffers new samples against a resident dataset (inline `rows`,
//! each `{"x":[p numbers],"y":[q numbers]}`, or a dataset file via `path` —
//! exactly one source; 1..=[`MAX_APPEND_ROWS`] inline rows per request;
//! non-finite values are parse errors). Buffered rows take effect at the
//! next `refit`: the job folds them into the window (evicting the oldest
//! samples beyond the optional `"window"` occupancy cap), applies the
//! incremental rank-k statistics correction, and re-solves warm from the
//! cached model — re-fit cost scales with the drift, not the dataset.
//!
//! Job requests (`fit` / `path` / `cv` / `refit`) carry solver parameters under the
//! *same keys as config files* — the engine layers them onto its base
//! [`crate::coordinator::RunConfig`] via the one shared schema, so an
//! unknown or malformed key fails with the same message a bad config file
//! would. `"warm": false` opts a job out of the registry's cached-model
//! warm start. `"stream": true` opts a `path`/`cv` job into per-λ-point
//! progress lines (below); old clients that never set it still get exactly
//! one terminal response per request.
//!
//! Responses echo the request `id` and `op`:
//!
//! ```text
//! {"id":3,"op":"fit","ok":true,"result":{...}}
//! {"id":9,"op":"fit","ok":false,"error":{"kind":"budget","message":"..."}}
//! ```
//!
//! A streamed job additionally emits zero or more non-terminal progress
//! lines *before* its terminal response. A progress line carries a
//! `progress` object and — the discriminator — **no `ok` key**:
//!
//! ```text
//! {"id":4,"op":"path","progress":{"point":0,"lambda_l":0.5, ...}}
//! {"id":4,"op":"path","progress":{"point":1, ...}}
//! {"id":4,"op":"path","ok":true,"result":{...}}
//! ```
//!
//! Error kinds are closed ([`ErrKind`]): `parse`, `not_found`, `budget`,
//! `busy`, `io`, `solve`, `cancelled`, `shutdown`. A failed job never takes
//! the session down — the next line is served normally.

use crate::datagen::Workload;
use crate::util::json::Json;

/// A parsed request line.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen correlation id, echoed in the response (0 if absent).
    pub id: u64,
    pub op: Op,
}

/// Request operations.
#[derive(Clone, Debug)]
pub enum Op {
    Load(LoadOp),
    Job(JobOp),
    /// Buffer new samples against a resident dataset (applied by `refit`).
    Append(AppendOp),
    Stat { dataset: Option<String> },
    Evict { dataset: String },
    /// Cooperatively cancel the job(s) submitted under request id `job`.
    Cancel { job: u64 },
    /// Persist a registry entry's cached model to a JSONL model file.
    Save(SaveOp),
    /// Return a registry entry's cached model inline (exact-f64 JSON).
    Export {
        dataset: String,
        /// Solver whose cached model to export; `None` = the serving
        /// process's default solver.
        solver: Option<String>,
    },
    Shutdown,
}

/// Bring a dataset into the registry (idempotent: re-loading a resident
/// name is a cheap hit).
#[derive(Clone, Debug)]
pub struct LoadOp {
    pub name: String,
    pub source: LoadSource,
    /// Eagerly materialize the dense statistics (default `true`) so later
    /// jobs start warm; `false` defers them to first use.
    pub warm: bool,
    /// Optional model file (written by `save`) to seed the entry's
    /// warm-start cache from, so a fitted model survives eviction and
    /// restart.
    pub model: Option<String>,
    /// Storage policy for a `path` load: `"mem"` (default) loads the file
    /// resident; `"disk"` binds a sharded `CGGMPAN1` panel file out-of-core
    /// behind the registry-budget-tracked panel cache, so admission prices
    /// the cache rather than the full X/Y matrices.
    pub storage: Option<String>,
}

/// Persist the cached model of `dataset` (for `solver`, default the serving
/// process's solver) to `path` via the checkpoint writer's exact-f64 JSONL.
#[derive(Clone, Debug)]
pub struct SaveOp {
    pub dataset: String,
    pub path: String,
    pub solver: Option<String>,
}

/// Upper bound on inline rows per `append` request — a closed, documented
/// limit so a hostile client cannot stage an unbounded buffer through one
/// line (the 1 MiB line cap bounds bytes; this bounds row *count*).
pub const MAX_APPEND_ROWS: usize = 4096;

/// Buffer new samples for `dataset`, to be folded into its window by the
/// next `refit`. Exactly one of `rows` (inline, shape-checked against the
/// dataset at execution) or `path` (a dataset file whose samples are
/// appended) is present.
#[derive(Clone, Debug)]
pub struct AppendOp {
    pub dataset: String,
    /// Inline samples, `(x, y)` per row. Values are finite (parse-enforced).
    pub rows: Vec<(Vec<f64>, Vec<f64>)>,
    /// Dataset file to append from instead of inline rows.
    pub path: Option<String>,
}

/// Where a `load` gets its data.
#[derive(Clone, Debug)]
pub enum LoadSource {
    /// A dataset file written by `cggm gen` / `coordinator::save_dataset`.
    Path(String),
    /// A synthetic workload, generated in-process.
    Generate {
        workload: Workload,
        p: usize,
        q: usize,
        n: usize,
        seed: u64,
    },
}

/// The solver job shapes, admission-controlled and queued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Fit,
    Path,
    Cv,
    /// Fold buffered `append` rows into the dataset's sliding window,
    /// incrementally correct its cached statistics, and re-solve warm from
    /// the cached model.
    Refit,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Fit => "fit",
            JobKind::Path => "path",
            JobKind::Cv => "cv",
            JobKind::Refit => "refit",
        }
    }
}

/// A solver job against a registered dataset.
#[derive(Clone, Debug)]
pub struct JobOp {
    pub kind: JobKind,
    pub dataset: String,
    /// Warm-start from the registry's cached model when one exists
    /// (default `true`; `fit` only — paths warm internally).
    pub warm: bool,
    /// Emit per-λ-point progress lines before the terminal response
    /// (default `false`; `path`/`cv` only — `fit` has no per-point grain).
    pub stream: bool,
    /// `refit` only: after folding buffered appends in, evict the oldest
    /// samples until window occupancy is at most this (`None` = keep all).
    pub window: Option<usize>,
    /// Remaining request keys, layered onto the engine's base config.
    pub params: Vec<(String, Json)>,
}

impl Request {
    /// The response `op` label for this request.
    pub fn op_name(&self) -> &'static str {
        match &self.op {
            Op::Load(_) => "load",
            Op::Job(j) => j.kind.name(),
            Op::Append(_) => "append",
            Op::Stat { .. } => "stat",
            Op::Evict { .. } => "evict",
            Op::Cancel { .. } => "cancel",
            Op::Save(_) => "save",
            Op::Export { .. } => "export",
            Op::Shutdown => "shutdown",
        }
    }

    /// The dataset a queued instance of this request will touch (admission
    /// and sequencing key), if any.
    pub fn dataset_name(&self) -> Option<&str> {
        match &self.op {
            Op::Load(l) => Some(&l.name),
            Op::Job(j) => Some(&j.dataset),
            Op::Append(a) => Some(&a.dataset),
            Op::Evict { dataset } => Some(dataset),
            Op::Stat { dataset } => dataset.as_deref(),
            Op::Save(s) => Some(&s.dataset),
            Op::Export { dataset, .. } => Some(dataset),
            Op::Cancel { .. } | Op::Shutdown => None,
        }
    }

    /// Parse one request line.
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let doc = Json::parse(line.trim()).map_err(|e| e.to_string())?;
        Self::parse(&doc)
    }

    /// Parse a request object (batch manifests hand these over directly).
    pub fn parse(doc: &Json) -> Result<Request, String> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| "request must be a JSON object".to_string())?;
        let op = doc
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "request missing string 'op'".to_string())?;
        // An absent id defaults to 0; a *present but invalid* id is an
        // error (the seed's saturating cast silently mangled negative,
        // fractional, and > 2^53 ids — the echoed id then correlated the
        // response with the wrong request).
        let id = match doc.get("id") {
            None => 0,
            Some(v) => v.as_u64().ok_or_else(|| {
                "'id' must be a non-negative integer below 2^53".to_string()
            })?,
        };
        let str_field = |key: &str| -> Result<String, String> {
            doc.get(key)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| format!("'{op}' requires string '{key}'"))
        };
        let warm = doc.get("warm").and_then(|v| v.as_bool()).unwrap_or(true);
        let parsed = match op {
            "load" => {
                let name = str_field("name")?;
                let source = if doc.get("path").is_some() {
                    LoadSource::Path(str_field("path")?)
                } else {
                    let dim = |key: &str| -> Result<usize, String> {
                        doc.get(key)
                            .and_then(|v| v.as_usize())
                            .ok_or_else(|| format!("'load' requires int '{key}' (or 'path')"))
                    };
                    let w = str_field("workload")?;
                    LoadSource::Generate {
                        workload: Workload::parse(&w)
                            .ok_or_else(|| format!("unknown workload '{w}'"))?,
                        p: dim("p")?,
                        q: dim("q")?,
                        n: dim("n")?,
                        seed: match doc.get("seed") {
                            None => 1,
                            Some(v) => v.as_u64().ok_or_else(|| {
                                "'seed' must be a non-negative integer below 2^53".to_string()
                            })?,
                        },
                    }
                };
                let model = doc
                    .get("model")
                    .map(|v| {
                        v.as_str()
                            .map(String::from)
                            .ok_or_else(|| "'model' must be a string path".to_string())
                    })
                    .transpose()?;
                let storage = doc
                    .get("storage")
                    .map(|v| {
                        let s = v
                            .as_str()
                            .ok_or_else(|| "'storage' must be a string".to_string())?;
                        if s != "mem" && s != "disk" {
                            return Err(format!(
                                "'storage' must be \"mem\" or \"disk\", got '{s}'"
                            ));
                        }
                        Ok(s.to_string())
                    })
                    .transpose()?;
                if matches!(storage.as_deref(), Some("disk"))
                    && !matches!(source, LoadSource::Path(_))
                {
                    return Err(
                        "'storage':\"disk\" requires a 'path' source (generated \
                         workloads are resident; write them with `gen --storage \
                         disk` first)"
                            .to_string(),
                    );
                }
                Op::Load(LoadOp {
                    name,
                    source,
                    warm,
                    model,
                    storage,
                })
            }
            "fit" | "path" | "cv" | "refit" => {
                let kind = match op {
                    "fit" => JobKind::Fit,
                    "path" => JobKind::Path,
                    "cv" => JobKind::Cv,
                    _ => JobKind::Refit,
                };
                let dataset = str_field("dataset")?;
                let stream = doc.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
                // `window` is a refit control key (occupancy cap), not a
                // solver parameter; on other jobs it falls through to the
                // config layering and fails there as an unknown key.
                let window = if kind == JobKind::Refit {
                    match doc.get("window") {
                        None => None,
                        Some(v) => {
                            let w = v.as_usize().ok_or_else(|| {
                                "'window' must be a non-negative integer below 2^53".to_string()
                            })?;
                            if w == 0 {
                                return Err("'window' must be >= 1".to_string());
                            }
                            Some(w)
                        }
                    }
                } else {
                    None
                };
                // Everything that is not addressing/control is a solver
                // parameter for the engine's config layering.
                let reserved: &[&str] = if kind == JobKind::Refit {
                    &["op", "id", "dataset", "warm", "stream", "window"]
                } else {
                    &["op", "id", "dataset", "warm", "stream"]
                };
                let params: Vec<(String, Json)> = obj
                    .iter()
                    .filter(|(k, _)| !reserved.contains(&k.as_str()))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                Op::Job(JobOp {
                    kind,
                    dataset,
                    warm,
                    stream,
                    window,
                    params,
                })
            }
            "append" => {
                let dataset = str_field("dataset")?;
                let path = doc
                    .get("path")
                    .map(|v| {
                        v.as_str()
                            .map(String::from)
                            .ok_or_else(|| "'path' must be a string".to_string())
                    })
                    .transpose()?;
                let rows = match doc.get("rows") {
                    None => None,
                    Some(v) => {
                        let arr = v
                            .as_arr()
                            .ok_or_else(|| "'rows' must be an array of objects".to_string())?;
                        if arr.len() > MAX_APPEND_ROWS {
                            return Err(format!(
                                "'rows' exceeds the {MAX_APPEND_ROWS}-row per-request limit"
                            ));
                        }
                        if arr.is_empty() {
                            return Err("'rows' must contain at least one row".to_string());
                        }
                        let vec_field = |row: &Json, key: &str| -> Result<Vec<f64>, String> {
                            let vals = row.get(key).and_then(|a| a.as_arr()).ok_or_else(|| {
                                format!("each append row requires number array '{key}'")
                            })?;
                            vals.iter()
                                .map(|e| {
                                    e.as_f64().filter(|f| f.is_finite()).ok_or_else(|| {
                                        format!("append row '{key}' values must be finite numbers")
                                    })
                                })
                                .collect()
                        };
                        Some(
                            arr.iter()
                                .map(|row| Ok((vec_field(row, "x")?, vec_field(row, "y")?)))
                                .collect::<Result<Vec<_>, String>>()?,
                        )
                    }
                };
                match (rows, &path) {
                    (Some(rows), None) => Op::Append(AppendOp {
                        dataset,
                        rows,
                        path: None,
                    }),
                    (None, Some(_)) => Op::Append(AppendOp {
                        dataset,
                        rows: Vec::new(),
                        path,
                    }),
                    _ => {
                        return Err(
                            "'append' requires exactly one of 'rows' or 'path'".to_string()
                        )
                    }
                }
            }
            "stat" => Op::Stat {
                dataset: doc
                    .get("dataset")
                    .and_then(|v| v.as_str())
                    .map(String::from),
            },
            "evict" => Op::Evict {
                dataset: str_field("dataset")?,
            },
            "cancel" => Op::Cancel {
                job: doc
                    .get("job")
                    .ok_or_else(|| "'cancel' requires 'job' (a request id)".to_string())?
                    .as_u64()
                    .ok_or_else(|| {
                        "'job' must be a non-negative integer below 2^53".to_string()
                    })?,
            },
            "save" => Op::Save(SaveOp {
                dataset: str_field("dataset")?,
                path: str_field("path")?,
                solver: doc.get("solver").and_then(|v| v.as_str()).map(String::from),
            }),
            "export" => Op::Export {
                dataset: str_field("dataset")?,
                solver: doc.get("solver").and_then(|v| v.as_str()).map(String::from),
            },
            "shutdown" => Op::Shutdown,
            other => return Err(format!("unknown op '{other}'")),
        };
        Ok(Request { id, op: parsed })
    }
}

/// Closed error taxonomy; `kind` is machine-matchable, `message` is for
/// humans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrKind {
    /// Malformed request line or unknown/invalid job parameter.
    Parse,
    /// Dataset not resident in the registry.
    NotFound,
    /// The shared memory budget cannot (ever) hold this work.
    Budget,
    /// The dataset is held by a running job (evict/reload).
    Busy,
    /// Filesystem failure (dataset load).
    Io,
    /// The solver failed (line search, factorization, panic).
    Solve,
    /// The job was cancelled cooperatively (`cancel` op).
    Cancelled,
    /// The engine is shutting down; no further jobs are accepted.
    Shutdown,
}

impl ErrKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrKind::Parse => "parse",
            ErrKind::NotFound => "not_found",
            ErrKind::Budget => "budget",
            ErrKind::Busy => "busy",
            ErrKind::Io => "io",
            ErrKind::Solve => "solve",
            ErrKind::Cancelled => "cancelled",
            ErrKind::Shutdown => "shutdown",
        }
    }
}

/// A response line.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub op: String,
    /// `Ok(result)` or `Err((kind, message))`.
    pub outcome: Result<Json, (ErrKind, String)>,
}

impl Response {
    pub fn ok(id: u64, op: &str, result: Json) -> Response {
        Response {
            id,
            op: op.to_string(),
            outcome: Ok(result),
        }
    }

    pub fn err(id: u64, op: &str, kind: ErrKind, message: impl Into<String>) -> Response {
        Response {
            id,
            op: op.to_string(),
            outcome: Err((kind, message.into())),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }

    /// The result object (`None` for errors) — test/introspection helper.
    pub fn result(&self) -> Option<&Json> {
        self.outcome.as_ref().ok()
    }

    /// The error kind (`None` for successes).
    pub fn err_kind(&self) -> Option<ErrKind> {
        self.outcome.as_ref().err().map(|(k, _)| *k)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("op", Json::str(self.op.clone())),
            ("ok", Json::Bool(self.outcome.is_ok())),
        ];
        match &self.outcome {
            Ok(result) => fields.push(("result", result.clone())),
            Err((kind, message)) => fields.push((
                "error",
                Json::obj(vec![
                    ("kind", Json::str(kind.as_str())),
                    ("message", Json::str(message.clone())),
                ]),
            )),
        }
        Json::obj(fields)
    }
}

/// A non-terminal per-λ-point progress line for a streamed job. On the
/// wire it carries a `progress` object and — deliberately — **no `ok`
/// key**, so clients discriminate terminal responses by `ok`'s presence.
#[derive(Clone, Debug)]
pub struct Progress {
    pub id: u64,
    pub op: String,
    /// The per-point payload (`point`, `lambda_l`, `f`, … for `path`;
    /// `fold`/`point`/`heldout_nll` for `cv`).
    pub body: Json,
}

impl Progress {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("op", Json::str(self.op.clone())),
            ("progress", self.body.clone()),
        ])
    }
}

/// One line the server writes: a streamed progress event or the terminal
/// response. Engine reply channels carry these so per-connection writers
/// interleave progress and terminals in submission order.
#[derive(Clone, Debug)]
pub enum ServerLine {
    Progress(Progress),
    Done(Response),
}

impl ServerLine {
    pub fn to_json(&self) -> Json {
        match self {
            ServerLine::Progress(p) => p.to_json(),
            ServerLine::Done(r) => r.to_json(),
        }
    }

    /// The request id this line belongs to.
    pub fn id(&self) -> u64 {
        match self {
            ServerLine::Progress(p) => p.id,
            ServerLine::Done(r) => r.id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_op() {
        let r = Request::parse_line(
            r#"{"op":"load","id":1,"name":"d","workload":"chain","p":8,"q":9,"n":10}"#,
        )
        .unwrap();
        assert_eq!(r.id, 1);
        assert_eq!(r.op_name(), "load");
        let Op::Load(l) = &r.op else { panic!() };
        assert!(l.warm, "warm defaults on");
        let LoadSource::Generate { p, q, n, seed, .. } = &l.source else {
            panic!()
        };
        assert_eq!((*p, *q, *n, *seed), (8, 9, 10, 1));

        let r = Request::parse_line(r#"{"op":"load","id":2,"name":"d","path":"x.bin"}"#).unwrap();
        let Op::Load(l) = &r.op else { panic!() };
        assert!(matches!(&l.source, LoadSource::Path(p) if p == "x.bin"));

        let r = Request::parse_line(
            r#"{"op":"fit","id":3,"dataset":"d","solver":"alt","lambda":0.4,"warm":false}"#,
        )
        .unwrap();
        assert_eq!(r.dataset_name(), Some("d"));
        let Op::Job(j) = &r.op else { panic!() };
        assert_eq!(j.kind, JobKind::Fit);
        assert!(!j.warm);
        // Addressing keys are stripped; solver params pass through.
        let keys: Vec<&str> = j.params.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["lambda", "solver"]);

        for (line, want) in [
            (r#"{"op":"path","dataset":"d"}"#, JobKind::Path),
            (r#"{"op":"cv","dataset":"d","cv_folds":3}"#, JobKind::Cv),
        ] {
            let r = Request::parse_line(line).unwrap();
            let Op::Job(j) = &r.op else { panic!() };
            assert_eq!(j.kind, want);
            assert_eq!(r.id, 0, "id defaults to 0");
        }

        assert!(matches!(
            Request::parse_line(r#"{"op":"stat"}"#).unwrap().op,
            Op::Stat { dataset: None }
        ));
        assert!(matches!(
            Request::parse_line(r#"{"op":"evict","dataset":"d"}"#)
                .unwrap()
                .op,
            Op::Evict { .. }
        ));
        assert!(matches!(
            Request::parse_line(r#"{"op":"cancel","id":9,"job":4}"#)
                .unwrap()
                .op,
            Op::Cancel { job: 4 }
        ));
        assert!(matches!(
            Request::parse_line(r#"{"op":"shutdown"}"#).unwrap().op,
            Op::Shutdown
        ));
    }

    #[test]
    fn parses_stream_save_export_and_model_seed() {
        // `stream` defaults off, parses as a control key (never a param).
        let r = Request::parse_line(r#"{"op":"path","dataset":"d","path_points":4}"#).unwrap();
        let Op::Job(j) = &r.op else { panic!() };
        assert!(!j.stream, "stream defaults off");
        let r = Request::parse_line(
            r#"{"op":"path","dataset":"d","stream":true,"path_points":4}"#,
        )
        .unwrap();
        let Op::Job(j) = &r.op else { panic!() };
        assert!(j.stream);
        let keys: Vec<&str> = j.params.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["path_points"], "stream is not a solver param");

        let r = Request::parse_line(
            r#"{"op":"save","id":1,"dataset":"d","path":"m.jsonl","solver":"alt"}"#,
        )
        .unwrap();
        assert_eq!(r.op_name(), "save");
        assert_eq!(r.dataset_name(), Some("d"));
        let Op::Save(s) = &r.op else { panic!() };
        assert_eq!((s.path.as_str(), s.solver.as_deref()), ("m.jsonl", Some("alt")));

        let r = Request::parse_line(r#"{"op":"export","dataset":"d"}"#).unwrap();
        assert_eq!(r.op_name(), "export");
        assert!(matches!(&r.op, Op::Export { solver: None, .. }));

        // `load` accepts an optional saved-model seed path.
        let r = Request::parse_line(
            r#"{"op":"load","name":"d","path":"x.bin","model":"m.jsonl"}"#,
        )
        .unwrap();
        let Op::Load(l) = &r.op else { panic!() };
        assert_eq!(l.model.as_deref(), Some("m.jsonl"));
        assert_eq!(l.storage, None, "storage defaults to the engine policy");
    }

    #[test]
    fn parses_and_rejects_storage_modes() {
        let r = Request::parse_line(
            r#"{"op":"load","name":"d","path":"x.pan","storage":"disk"}"#,
        )
        .unwrap();
        let Op::Load(l) = &r.op else { panic!() };
        assert_eq!(l.storage.as_deref(), Some("disk"));
        let r = Request::parse_line(
            r#"{"op":"load","name":"d","path":"x.bin","storage":"mem"}"#,
        )
        .unwrap();
        let Op::Load(l) = &r.op else { panic!() };
        assert_eq!(l.storage.as_deref(), Some("mem"));
        for line in [
            // unknown mode / non-string
            r#"{"op":"load","name":"d","path":"x.bin","storage":"tape"}"#,
            r#"{"op":"load","name":"d","path":"x.bin","storage":7}"#,
            // disk storage needs a file to stream from
            r#"{"op":"load","name":"d","workload":"chain","p":4,"q":4,"n":4,"storage":"disk"}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
    }

    #[test]
    fn parses_append_and_refit() {
        let r = Request::parse_line(
            r#"{"op":"append","id":12,"dataset":"d","rows":[{"x":[1.0,2.0],"y":[3.0]},{"x":[4,5],"y":[6]}]}"#,
        )
        .unwrap();
        assert_eq!(r.op_name(), "append");
        assert_eq!(r.dataset_name(), Some("d"));
        let Op::Append(a) = &r.op else { panic!() };
        assert_eq!(a.rows.len(), 2);
        assert_eq!(a.rows[0].0, vec![1.0, 2.0]);
        assert_eq!(a.rows[1].1, vec![6.0]);
        assert!(a.path.is_none());

        let r = Request::parse_line(r#"{"op":"append","dataset":"d","path":"more.bin"}"#).unwrap();
        let Op::Append(a) = &r.op else { panic!() };
        assert_eq!(a.path.as_deref(), Some("more.bin"));
        assert!(a.rows.is_empty());

        let r = Request::parse_line(
            r#"{"op":"refit","id":14,"dataset":"d","window":100,"lambda":0.4}"#,
        )
        .unwrap();
        assert_eq!(r.op_name(), "refit");
        let Op::Job(j) = &r.op else { panic!() };
        assert_eq!(j.kind, JobKind::Refit);
        assert!(j.warm, "refit warm-starts by default");
        assert_eq!(j.window, Some(100));
        // `window` is a control key, never a solver param.
        let keys: Vec<&str> = j.params.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["lambda"]);

        // On non-refit jobs `window` stays a param (rejected downstream by
        // the shared config schema).
        let r = Request::parse_line(r#"{"op":"fit","dataset":"d","window":5}"#).unwrap();
        let Op::Job(j) = &r.op else { panic!() };
        assert_eq!(j.window, None);
        assert!(j.params.iter().any(|(k, _)| k == "window"));
    }

    #[test]
    fn rejects_hostile_append_payloads() {
        for line in [
            // no source / both sources
            r#"{"op":"append","dataset":"d"}"#,
            r#"{"op":"append","dataset":"d","rows":[],"path":"x.bin"}"#,
            r#"{"op":"append","dataset":"d","rows":[]}"#,
            // malformed rows
            r#"{"op":"append","dataset":"d","rows":7}"#,
            r#"{"op":"append","dataset":"d","rows":[7]}"#,
            r#"{"op":"append","dataset":"d","rows":[{"x":[1]}]}"#,
            r#"{"op":"append","dataset":"d","rows":[{"x":[1],"y":"no"}]}"#,
            r#"{"op":"append","dataset":"d","rows":[{"x":["a"],"y":[1]}]}"#,
            // non-finite values (1e999 parses to +inf)
            r#"{"op":"append","dataset":"d","rows":[{"x":[1e999],"y":[1]}]}"#,
            r#"{"op":"append","dataset":"d","rows":[{"x":[1],"y":[-1e999]}]}"#,
            // refit window must be a positive checked integer
            r#"{"op":"refit","dataset":"d","window":0}"#,
            r#"{"op":"refit","dataset":"d","window":-1}"#,
            r#"{"op":"refit","dataset":"d","window":2.5}"#,
            r#"{"op":"refit","dataset":"d","window":9007199254740992}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
        // The row-count cap is a structured parse error, not an allocation.
        let mut big = String::from(r#"{"op":"append","dataset":"d","rows":["#);
        for i in 0..=MAX_APPEND_ROWS {
            if i > 0 {
                big.push(',');
            }
            big.push_str(r#"{"x":[1],"y":[1]}"#);
        }
        big.push_str("]}");
        let err = Request::parse_line(&big).unwrap_err();
        assert!(err.contains("per-request limit"), "{err}");
    }

    #[test]
    fn rejects_malformed_requests() {
        for line in [
            "not json",
            "[1,2]",
            r#"{"id":1}"#,
            r#"{"op":"nope"}"#,
            r#"{"op":"load","name":"d"}"#,
            r#"{"op":"load","name":"d","workload":"wat","p":1,"q":1,"n":1}"#,
            r#"{"op":"fit"}"#,
            r#"{"op":"evict"}"#,
            r#"{"op":"cancel"}"#,
            r#"{"op":"cancel","job":-1}"#,
            r#"{"op":"cancel","job":1.5}"#,
            r#"{"op":"save","dataset":"d"}"#,
            r#"{"op":"save","path":"m.jsonl"}"#,
            r#"{"op":"export"}"#,
            r#"{"op":"load","name":"d","path":"x.bin","model":7}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
    }

    /// Regression: on the seed, the saturating `as usize` cast turned
    /// `{"p":-1}` into a 0-dimensional dataset and `{"p":1e300}` into a
    /// `usize::MAX` allocation request. Both must be clean parse errors.
    #[test]
    fn rejects_hostile_dimensions_and_ids() {
        for line in [
            r#"{"op":"load","name":"d","workload":"chain","p":-1,"q":8,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":1e300,"q":8,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":8,"q":2.5,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":8,"q":8,"n":8,"seed":-3}"#,
            r#"{"op":"stat","id":-1}"#,
            r#"{"op":"stat","id":1.5}"#,
            r#"{"op":"stat","id":9007199254740992}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
        // The largest safe id round-trips exactly.
        let r = Request::parse_line(r#"{"op":"stat","id":9007199254740991}"#).unwrap();
        assert_eq!(r.id, 9_007_199_254_740_991);
    }

    #[test]
    fn response_lines_roundtrip() {
        let ok = Response::ok(7, "fit", Json::obj(vec![("f", Json::num(1.5))]));
        let doc = Json::parse(&ok.to_json().to_string()).unwrap();
        assert_eq!(doc.get("id").and_then(|v| v.as_usize()), Some(7));
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            doc.get("result").and_then(|r| r.get("f")).and_then(|v| v.as_f64()),
            Some(1.5)
        );
        let err = Response::err(8, "fit", ErrKind::Budget, "too big");
        assert_eq!(err.err_kind(), Some(ErrKind::Budget));
        let doc = Json::parse(&err.to_json().to_string()).unwrap();
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(
            doc.get("error").and_then(|e| e.get("kind")).and_then(|v| v.as_str()),
            Some("budget")
        );
    }

    /// Progress lines must omit the `ok` key — that absence is how old
    /// clients and the batch driver tell them apart from terminals.
    #[test]
    fn progress_lines_have_no_ok_key() {
        let p = Progress {
            id: 4,
            op: "path".to_string(),
            body: Json::obj(vec![("point", Json::num(2.0))]),
        };
        let doc = Json::parse(&ServerLine::Progress(p).to_json().to_string()).unwrap();
        assert_eq!(doc.get("id").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(doc.get("op").and_then(|v| v.as_str()), Some("path"));
        assert!(doc.get("ok").is_none(), "progress lines carry no 'ok'");
        assert_eq!(
            doc.get("progress")
                .and_then(|b| b.get("point"))
                .and_then(|v| v.as_usize()),
            Some(2)
        );
        let done = ServerLine::Done(Response::ok(4, "path", Json::obj(vec![])));
        assert_eq!(done.id(), 4);
        let doc = Json::parse(&done.to_json().to_string()).unwrap();
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true));
    }
}
