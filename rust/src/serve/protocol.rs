//! JSONL wire protocol of `cggm serve` / `cggm batch`.
//!
//! One JSON object per line, in both directions. Requests:
//!
//! ```text
//! {"op":"load","id":1,"name":"expr","path":"expr.bin"}
//! {"op":"load","id":2,"name":"syn","workload":"chain","p":200,"q":200,"n":100,"seed":7}
//! {"op":"fit","id":3,"dataset":"syn","solver":"alt","lambda":0.4,"tol":0.001}
//! {"op":"path","id":4,"dataset":"syn","solver":"alt","path_points":8}
//! {"op":"cv","id":5,"dataset":"syn","cv_folds":5,"cv_threads":2}
//! {"op":"stat","id":6}
//! {"op":"evict","id":7,"dataset":"expr"}
//! {"op":"shutdown","id":8}
//! ```
//!
//! Job requests (`fit` / `path` / `cv`) carry solver parameters under the
//! *same keys as config files* — the engine layers them onto its base
//! [`crate::coordinator::RunConfig`] via the one shared schema, so an
//! unknown or malformed key fails with the same message a bad config file
//! would. `"warm": false` opts a job out of the registry's cached-model
//! warm start.
//!
//! Responses echo the request `id` and `op`:
//!
//! ```text
//! {"id":3,"op":"fit","ok":true,"result":{...}}
//! {"id":9,"op":"fit","ok":false,"error":{"kind":"budget","message":"..."}}
//! ```
//!
//! Error kinds are closed ([`ErrKind`]): `parse`, `not_found`, `budget`,
//! `busy`, `io`, `solve`, `shutdown`. A failed job never takes the session
//! down — the next line is served normally.

use crate::datagen::Workload;
use crate::util::json::Json;

/// A parsed request line.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen correlation id, echoed in the response (0 if absent).
    pub id: u64,
    pub op: Op,
}

/// Request operations.
#[derive(Clone, Debug)]
pub enum Op {
    Load(LoadOp),
    Job(JobOp),
    Stat { dataset: Option<String> },
    Evict { dataset: String },
    Shutdown,
}

/// Bring a dataset into the registry (idempotent: re-loading a resident
/// name is a cheap hit).
#[derive(Clone, Debug)]
pub struct LoadOp {
    pub name: String,
    pub source: LoadSource,
    /// Eagerly materialize the dense statistics (default `true`) so later
    /// jobs start warm; `false` defers them to first use.
    pub warm: bool,
}

/// Where a `load` gets its data.
#[derive(Clone, Debug)]
pub enum LoadSource {
    /// A dataset file written by `cggm gen` / `coordinator::save_dataset`.
    Path(String),
    /// A synthetic workload, generated in-process.
    Generate {
        workload: Workload,
        p: usize,
        q: usize,
        n: usize,
        seed: u64,
    },
}

/// The three solver job shapes, admission-controlled and queued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Fit,
    Path,
    Cv,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Fit => "fit",
            JobKind::Path => "path",
            JobKind::Cv => "cv",
        }
    }
}

/// A solver job against a registered dataset.
#[derive(Clone, Debug)]
pub struct JobOp {
    pub kind: JobKind,
    pub dataset: String,
    /// Warm-start from the registry's cached model when one exists
    /// (default `true`; `fit` only — paths warm internally).
    pub warm: bool,
    /// Remaining request keys, layered onto the engine's base config.
    pub params: Vec<(String, Json)>,
}

impl Request {
    /// The response `op` label for this request.
    pub fn op_name(&self) -> &'static str {
        match &self.op {
            Op::Load(_) => "load",
            Op::Job(j) => j.kind.name(),
            Op::Stat { .. } => "stat",
            Op::Evict { .. } => "evict",
            Op::Shutdown => "shutdown",
        }
    }

    /// The dataset a queued instance of this request will touch (admission
    /// and sequencing key), if any.
    pub fn dataset_name(&self) -> Option<&str> {
        match &self.op {
            Op::Load(l) => Some(&l.name),
            Op::Job(j) => Some(&j.dataset),
            Op::Evict { dataset } => Some(dataset),
            Op::Stat { dataset } => dataset.as_deref(),
            Op::Shutdown => None,
        }
    }

    /// Parse one request line.
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let doc = Json::parse(line.trim()).map_err(|e| e.to_string())?;
        Self::parse(&doc)
    }

    /// Parse a request object (batch manifests hand these over directly).
    pub fn parse(doc: &Json) -> Result<Request, String> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| "request must be a JSON object".to_string())?;
        let op = doc
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "request missing string 'op'".to_string())?;
        // An absent id defaults to 0; a *present but invalid* id is an
        // error (the seed's saturating cast silently mangled negative,
        // fractional, and > 2^53 ids — the echoed id then correlated the
        // response with the wrong request).
        let id = match doc.get("id") {
            None => 0,
            Some(v) => v.as_u64().ok_or_else(|| {
                "'id' must be a non-negative integer below 2^53".to_string()
            })?,
        };
        let str_field = |key: &str| -> Result<String, String> {
            doc.get(key)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| format!("'{op}' requires string '{key}'"))
        };
        let warm = doc.get("warm").and_then(|v| v.as_bool()).unwrap_or(true);
        let parsed = match op {
            "load" => {
                let name = str_field("name")?;
                let source = if doc.get("path").is_some() {
                    LoadSource::Path(str_field("path")?)
                } else {
                    let dim = |key: &str| -> Result<usize, String> {
                        doc.get(key)
                            .and_then(|v| v.as_usize())
                            .ok_or_else(|| format!("'load' requires int '{key}' (or 'path')"))
                    };
                    let w = str_field("workload")?;
                    LoadSource::Generate {
                        workload: Workload::parse(&w)
                            .ok_or_else(|| format!("unknown workload '{w}'"))?,
                        p: dim("p")?,
                        q: dim("q")?,
                        n: dim("n")?,
                        seed: match doc.get("seed") {
                            None => 1,
                            Some(v) => v.as_u64().ok_or_else(|| {
                                "'seed' must be a non-negative integer below 2^53".to_string()
                            })?,
                        },
                    }
                };
                Op::Load(LoadOp { name, source, warm })
            }
            "fit" | "path" | "cv" => {
                let kind = match op {
                    "fit" => JobKind::Fit,
                    "path" => JobKind::Path,
                    _ => JobKind::Cv,
                };
                let dataset = str_field("dataset")?;
                // Everything that is not addressing/control is a solver
                // parameter for the engine's config layering.
                let reserved = ["op", "id", "dataset", "warm"];
                let params: Vec<(String, Json)> = obj
                    .iter()
                    .filter(|(k, _)| !reserved.contains(&k.as_str()))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                Op::Job(JobOp {
                    kind,
                    dataset,
                    warm,
                    params,
                })
            }
            "stat" => Op::Stat {
                dataset: doc
                    .get("dataset")
                    .and_then(|v| v.as_str())
                    .map(String::from),
            },
            "evict" => Op::Evict {
                dataset: str_field("dataset")?,
            },
            "shutdown" => Op::Shutdown,
            other => return Err(format!("unknown op '{other}'")),
        };
        Ok(Request { id, op: parsed })
    }
}

/// Closed error taxonomy; `kind` is machine-matchable, `message` is for
/// humans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrKind {
    /// Malformed request line or unknown/invalid job parameter.
    Parse,
    /// Dataset not resident in the registry.
    NotFound,
    /// The shared memory budget cannot (ever) hold this work.
    Budget,
    /// The dataset is held by a running job (evict/reload).
    Busy,
    /// Filesystem failure (dataset load).
    Io,
    /// The solver failed (line search, factorization, panic).
    Solve,
    /// The engine is shutting down; no further jobs are accepted.
    Shutdown,
}

impl ErrKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrKind::Parse => "parse",
            ErrKind::NotFound => "not_found",
            ErrKind::Budget => "budget",
            ErrKind::Busy => "busy",
            ErrKind::Io => "io",
            ErrKind::Solve => "solve",
            ErrKind::Shutdown => "shutdown",
        }
    }
}

/// A response line.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub op: String,
    /// `Ok(result)` or `Err((kind, message))`.
    pub outcome: Result<Json, (ErrKind, String)>,
}

impl Response {
    pub fn ok(id: u64, op: &str, result: Json) -> Response {
        Response {
            id,
            op: op.to_string(),
            outcome: Ok(result),
        }
    }

    pub fn err(id: u64, op: &str, kind: ErrKind, message: impl Into<String>) -> Response {
        Response {
            id,
            op: op.to_string(),
            outcome: Err((kind, message.into())),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }

    /// The result object (`None` for errors) — test/introspection helper.
    pub fn result(&self) -> Option<&Json> {
        self.outcome.as_ref().ok()
    }

    /// The error kind (`None` for successes).
    pub fn err_kind(&self) -> Option<ErrKind> {
        self.outcome.as_ref().err().map(|(k, _)| *k)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("op", Json::str(self.op.clone())),
            ("ok", Json::Bool(self.outcome.is_ok())),
        ];
        match &self.outcome {
            Ok(result) => fields.push(("result", result.clone())),
            Err((kind, message)) => fields.push((
                "error",
                Json::obj(vec![
                    ("kind", Json::str(kind.as_str())),
                    ("message", Json::str(message.clone())),
                ]),
            )),
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_op() {
        let r = Request::parse_line(
            r#"{"op":"load","id":1,"name":"d","workload":"chain","p":8,"q":9,"n":10}"#,
        )
        .unwrap();
        assert_eq!(r.id, 1);
        assert_eq!(r.op_name(), "load");
        let Op::Load(l) = &r.op else { panic!() };
        assert!(l.warm, "warm defaults on");
        let LoadSource::Generate { p, q, n, seed, .. } = &l.source else {
            panic!()
        };
        assert_eq!((*p, *q, *n, *seed), (8, 9, 10, 1));

        let r = Request::parse_line(r#"{"op":"load","id":2,"name":"d","path":"x.bin"}"#).unwrap();
        let Op::Load(l) = &r.op else { panic!() };
        assert!(matches!(&l.source, LoadSource::Path(p) if p == "x.bin"));

        let r = Request::parse_line(
            r#"{"op":"fit","id":3,"dataset":"d","solver":"alt","lambda":0.4,"warm":false}"#,
        )
        .unwrap();
        assert_eq!(r.dataset_name(), Some("d"));
        let Op::Job(j) = &r.op else { panic!() };
        assert_eq!(j.kind, JobKind::Fit);
        assert!(!j.warm);
        // Addressing keys are stripped; solver params pass through.
        let keys: Vec<&str> = j.params.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["lambda", "solver"]);

        for (line, want) in [
            (r#"{"op":"path","dataset":"d"}"#, JobKind::Path),
            (r#"{"op":"cv","dataset":"d","cv_folds":3}"#, JobKind::Cv),
        ] {
            let r = Request::parse_line(line).unwrap();
            let Op::Job(j) = &r.op else { panic!() };
            assert_eq!(j.kind, want);
            assert_eq!(r.id, 0, "id defaults to 0");
        }

        assert!(matches!(
            Request::parse_line(r#"{"op":"stat"}"#).unwrap().op,
            Op::Stat { dataset: None }
        ));
        assert!(matches!(
            Request::parse_line(r#"{"op":"evict","dataset":"d"}"#)
                .unwrap()
                .op,
            Op::Evict { .. }
        ));
        assert!(matches!(
            Request::parse_line(r#"{"op":"shutdown"}"#).unwrap().op,
            Op::Shutdown
        ));
    }

    #[test]
    fn rejects_malformed_requests() {
        for line in [
            "not json",
            "[1,2]",
            r#"{"id":1}"#,
            r#"{"op":"nope"}"#,
            r#"{"op":"load","name":"d"}"#,
            r#"{"op":"load","name":"d","workload":"wat","p":1,"q":1,"n":1}"#,
            r#"{"op":"fit"}"#,
            r#"{"op":"evict"}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
    }

    /// Regression: on the seed, the saturating `as usize` cast turned
    /// `{"p":-1}` into a 0-dimensional dataset and `{"p":1e300}` into a
    /// `usize::MAX` allocation request. Both must be clean parse errors.
    #[test]
    fn rejects_hostile_dimensions_and_ids() {
        for line in [
            r#"{"op":"load","name":"d","workload":"chain","p":-1,"q":8,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":1e300,"q":8,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":8,"q":2.5,"n":8}"#,
            r#"{"op":"load","name":"d","workload":"chain","p":8,"q":8,"n":8,"seed":-3}"#,
            r#"{"op":"stat","id":-1}"#,
            r#"{"op":"stat","id":1.5}"#,
            r#"{"op":"stat","id":9007199254740992}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "{line}");
        }
        // The largest safe id round-trips exactly.
        let r = Request::parse_line(r#"{"op":"stat","id":9007199254740991}"#).unwrap();
        assert_eq!(r.id, 9_007_199_254_740_991);
    }

    #[test]
    fn response_lines_roundtrip() {
        let ok = Response::ok(7, "fit", Json::obj(vec![("f", Json::num(1.5))]));
        let doc = Json::parse(&ok.to_json().to_string()).unwrap();
        assert_eq!(doc.get("id").and_then(|v| v.as_usize()), Some(7));
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            doc.get("result").and_then(|r| r.get("f")).and_then(|v| v.as_f64()),
            Some(1.5)
        );
        let err = Response::err(8, "fit", ErrKind::Budget, "too big");
        assert_eq!(err.err_kind(), Some(ErrKind::Budget));
        let doc = Json::parse(&err.to_json().to_string()).unwrap();
        assert_eq!(doc.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(
            doc.get("error").and_then(|e| e.get("kind")).and_then(|v| v.as_str()),
            Some("budget")
        );
    }
}
