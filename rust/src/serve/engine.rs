//! The serve job engine: a bounded worker pool draining one FIFO queue of
//! admission-controlled jobs against the warm [`Registry`].
//!
//! # Admission control
//!
//! Every queued job carries a submit-time **peak-bytes estimate** built
//! from the same estimators the memwall suite pins:
//! [`dense_workingset_bytes`] for the solver's iterate-and-cache set,
//! [`dense_factor_bytes`] (×2: held factor + line-search trial) and
//! [`dense_factor_scratch_bytes`] for the Λ Cholesky,
//! [`NativeGemm::scratch_bytes_bound`] for engine-internal pack panels,
//! plus any dense statistics the target dataset has not materialized yet.
//! A job whose estimate can never fit — even with every other dataset
//! evicted — is **rejected** at submit with a structured `budget` error.
//! Everything else queues FIFO; a worker starts the head job only when
//! `live + reserved + estimate ≤ limit` over the shared [`MemBudget`]
//! (`reserved` = estimates of running jobs — conservative, since their
//! transients are also in `live`). When nothing is running and the head
//! still does not fit, idle LRU datasets are evicted to make room; if that
//! cannot help, the head fails with `budget` and the session keeps serving.
//!
//! The estimates schedule; the budget *enforces* — with one carve-out.
//! `fit` and `path` jobs register every allocation against the shared
//! budget, so even an underestimated job cannot push the process past the
//! cap: it fails fast with [`SolveError::Budget`] instead, mapping to the
//! same structured `budget` error. `cv` jobs inherit
//! [`cross_validate`](crate::coordinator::cross_validate)'s deliberate
//! per-fold budgeting: each fold gets an *independent* budget with the
//! shared limit (so concurrent folds cannot trip each other), and fold
//! data copies are raw input outside any budget — meaning a cv job's true
//! footprint can exceed the shared cap by up to its fold parallelism when
//! the estimate is low. Admission compensates by reserving
//! `cv_threads × (fold estimate + fold data)` for cv jobs; the hard
//! per-byte guarantee holds for everything except fold-internal work.
//!
//! # Ordering
//!
//! Claiming is strict FIFO, and a job whose dataset has an earlier `load`
//! still in flight waits for it — so a single-connection session behaves
//! sequentially-consistently (`load d` → `fit d` works with any worker
//! count), while jobs on unrelated datasets run concurrently up to
//! `serve_max_jobs`. Jobs on the *same* dataset additionally serialize on
//! the entry lock ([`WarmContext`] is single-threaded by design).
//!
//! Each worker installs the engine's persistent [`TeamPool`] for the
//! duration of a job, so the colored-CD team phases of every job reuse one
//! set of parked threads instead of spawning per pass.
//!
//! # Streaming, cancellation, and the job table
//!
//! Reply channels carry [`ServerLine`]s: zero or more `Progress` lines
//! (streamed `path`/`cv` jobs, opt-in per request) followed by exactly one
//! terminal `Done` response per submitted request. Every queued request
//! gets a ticketed slot in the scheduler's job table holding its state
//! (queued → running, or cancelled) and an armed [`CancelToken`] that the
//! executing solver polls at its wall-clock sites. `cancel` is handled
//! synchronously at submit: queued instances of the target id are reaped
//! (each answers with a `cancelled` error on its own connection), running
//! instances have their token flagged and terminate at the next poll with
//! the same structured error — their reservation is released by the normal
//! worker epilogue, so the admission invariant survives cancel storms.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use super::protocol::{
    AppendOp, ErrKind, JobKind, JobOp, LoadOp, LoadSource, Op, Progress, Request, Response,
    SaveOp, ServerLine,
};
use super::registry::{Registry, RegistryError, WarmContext};
use crate::cggm::factor::{dense_factor_bytes, dense_factor_scratch_bytes};
use crate::cggm::{CggmModel, Dataset, SampleBlock, WindowDelta};
use crate::linalg::dense::Mat;
use crate::coordinator::{self, checkpoint, RunConfig, RunSummary};
use crate::gemm::native::NativeGemm;
use crate::gemm::GemmEngine;
use crate::cggm::tiles::TileStats;
use crate::solvers::{
    dense_workingset_bytes, solve_in_context, CancelToken, SolveError, SolverKind, StatMode,
};
use crate::util::json::Json;
use crate::util::membudget::{fmt_bytes, MemBudget};
use crate::util::threadpool::TeamPool;
use crate::util::timer::Stopwatch;

/// Raw dataset bytes (feature-major X and Y).
fn data_bytes(p: usize, q: usize, n: usize) -> usize {
    8 * n * (p + q)
}

/// Bytes of all three dense statistics (`S_yy`, `S_xx`, `S_xy`).
fn stats_bytes(p: usize, q: usize) -> usize {
    8 * (q * q + p * p + p * q)
}

/// Minimum resident footprint of the tiled statistics layer during a job:
/// two streaming `tile × n` feature panels plus one `tile × tile` Gram
/// tile. The LRU tile cache can grow past this, but only into budget that
/// is actually *available* (excess tiles spill to disk instead of
/// allocating), so admission reserves just the floor — capped by the dense
/// statistics, which a small problem's tile layer never exceeds.
pub fn tiled_stats_floor(tile: usize, p: usize, q: usize, n: usize) -> usize {
    (16 * tile * n + 8 * tile * tile).min(stats_bytes(p, q))
}

/// Estimated peak working-set bytes of one `fit` (or one λ-path point —
/// the path driver reuses the same working set across points). `stats`
/// adds the dense statistics a cold context would materialize during the
/// job (0 once the registry entry is warm, or for the block solver, which
/// never forms them).
pub fn fit_estimate(kind: SolverKind, p: usize, q: usize, threads: usize) -> usize {
    dense_workingset_bytes(kind, p, q)
        + 2 * dense_factor_bytes(q)
        + dense_factor_scratch_bytes(q)
        + NativeGemm::scratch_bytes_bound(threads)
}

/// Estimated peak bytes of a `load`: the raw arrays plus (when eagerly
/// warming) the statistics and the Gram products' engine scratch.
pub fn load_estimate(p: usize, q: usize, n: usize, warm: bool, threads: usize) -> usize {
    let warm_cost = if warm {
        stats_bytes(p, q) + NativeGemm::scratch_bytes_bound(threads)
    } else {
        0
    };
    data_bytes(p, q, n) + warm_cost
}

/// Job-request keys that must not override the serving process's identity
/// (problem shape belongs to `load`; budgets, transports, and engines are
/// fixed at `cggm serve` startup). `stat_mode`/`stat_tile` are here because
/// a warm context's statistics layout is fixed when the context is built —
/// a per-job override would be silently ignored, so reject it loudly.
/// Likewise the `gemm_*` keys configure the engine, built once at startup.
const FORBIDDEN_JOB_KEYS: &[&str] = &[
    "workload",
    "p",
    "q",
    "n",
    "engine",
    "tile",
    "stat_mode",
    "stat_tile",
    "gemm_autotune",
    "gemm_blocks",
    "mem_budget",
    "checkpoint",
    "out_dir",
    "serve_max_jobs",
    "serve_budget",
    "serve_socket",
];

/// Submit-time shape knowledge: populated when a `load` is accepted, so
/// jobs queued right behind it can be sized before it finishes.
#[derive(Clone, Copy)]
struct Dims {
    p: usize,
    q: usize,
    n: usize,
    /// Whether the dense statistics are (or will be, once the pending load
    /// completes) materialized.
    warm: bool,
    /// Whether the dataset is (or will be) disk-backed — its resident
    /// footprint is then the panel-cache ceiling, not the dense arrays.
    disk: bool,
}

struct Queued {
    req: Request,
    est: usize,
    reply: mpsc::Sender<ServerLine>,
    /// Engine-unique handle tying this instance to its [`JobSlot`] (client
    /// ids are client-chosen and freely duplicated).
    ticket: u64,
    token: CancelToken,
    /// Whether this request opted into per-λ-point progress lines.
    stream: bool,
}

/// Per-request lifecycle state, reported by `stat` and targeted by `cancel`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobState {
    Queued,
    Running,
    /// Cancel requested; a queued instance never starts, a running one
    /// terminates at its next token poll.
    Cancelled,
}

impl JobState {
    fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Cancelled => "cancelled",
        }
    }
}

/// One live (queued or running) request in the scheduler's job table; the
/// slot is removed when its terminal response has been sent.
struct JobSlot {
    ticket: u64,
    /// Client request id — the `cancel` op's addressing key.
    id: u64,
    op: &'static str,
    state: JobState,
    stream: bool,
    token: CancelToken,
}

struct Sched {
    queue: VecDeque<Queued>,
    /// Estimates of currently running jobs.
    reserved: usize,
    running: usize,
    /// Live request slots (queued + running), in submission order.
    jobs: Vec<JobSlot>,
    next_ticket: u64,
    /// Dataset names whose `load` is executing right now. Combined with
    /// strict head-of-line claiming this gives per-dataset sequential
    /// consistency: a job queued behind a load of its dataset cannot be
    /// claimed until that load (claimed earlier, FIFO) has completed. A
    /// second load of a running name also waits, then resolves as a cheap
    /// idempotent hit.
    active_loads: std::collections::HashSet<String>,
    shutdown: bool,
}

struct Inner {
    base: RunConfig,
    gemm: Arc<dyn GemmEngine>,
    budget: MemBudget,
    registry: Mutex<Registry>,
    sched: Mutex<Sched>,
    work: Condvar,
    pool: Option<Arc<TeamPool>>,
    dims: Mutex<HashMap<String, Dims>>,
    completed: AtomicUsize,
    failed: AtomicUsize,
    rejected: AtomicUsize,
    cancelled: AtomicUsize,
    shutdown: AtomicBool,
}

/// The long-lived serving engine; see the module docs. Construct once,
/// [`Self::submit`] requests from any thread, [`Self::join`] at the end.
pub struct ServeEngine {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    /// Build an engine from a run config (its `serve_*` keys size the
    /// worker pool and shared budget; the rest is the per-job defaults that
    /// request keys layer over).
    pub fn new(mut base: RunConfig, gemm: Arc<dyn GemmEngine>) -> ServeEngine {
        // Serve jobs must never share one path-checkpoint file; the CLI
        // `--checkpoint` flag belongs to `cggm path`/`cggm cv`, not here.
        base.checkpoint = None;
        let budget = base
            .serve_budget
            .map(MemBudget::new)
            .unwrap_or_else(MemBudget::unlimited);
        let team_threads = base.threads.max(base.cd_threads);
        let pool = (team_threads > 1).then(|| Arc::new(TeamPool::new(team_threads)));
        let workers = base.serve_max_jobs.max(1);
        let inner = Arc::new(Inner {
            base,
            gemm,
            budget: budget.clone(),
            registry: Mutex::new(Registry::new(budget)),
            sched: Mutex::new(Sched {
                queue: VecDeque::new(),
                reserved: 0,
                running: 0,
                jobs: Vec::new(),
                next_ticket: 0,
                active_loads: std::collections::HashSet::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            pool,
            dims: Mutex::new(HashMap::new()),
            completed: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        ServeEngine {
            inner,
            workers: handles,
        }
    }

    /// The shared registry/job budget (tests pin `peak() ≤ limit`).
    pub fn budget(&self) -> &MemBudget {
        &self.inner.budget
    }

    /// Bytes currently reserved by admitted-but-unreleased job estimates.
    /// Exposed so the abuse suite can assert the admission invariant
    /// `budget().live() + reserved_bytes() ≤ limit (+ slack)` while jobs
    /// are in flight, not just at quiescence.
    pub fn reserved_bytes(&self) -> usize {
        self.inner.sched.lock().unwrap().reserved
    }

    /// Number of admitted jobs that may run concurrently.
    pub fn max_jobs(&self) -> usize {
        self.workers.len()
    }

    pub fn is_shutdown(&self) -> bool {
        self.inner.shutdown.load(Ordering::Relaxed)
    }

    /// Submit one request; its progress lines (streamed jobs) and terminal
    /// response are sent to `reply`. Control decisions (parse/shape
    /// validation, can-never-fit rejection, cancel, shutdown) respond
    /// immediately; everything else queues FIFO.
    pub fn submit(&self, req: Request, reply: &mpsc::Sender<ServerLine>) {
        let op = req.op_name();
        let id = req.id;
        if self.is_shutdown() {
            let _ = reply.send(ServerLine::Done(Response::err(
                id,
                op,
                ErrKind::Shutdown,
                "engine is shutting down",
            )));
            return;
        }
        if let Op::Cancel { job } = req.op {
            // Synchronous: a cancel must reach a long-running job *now*,
            // not after it in the FIFO queue.
            let _ = reply.send(ServerLine::Done(self.cancel_job(id, job)));
            return;
        }
        if let Op::Shutdown = req.op {
            // Stop accepting immediately, but queue the ack like any other
            // job so responses stay in FIFO order behind still-pending work
            // (workers drain the whole queue, shutdown included, then exit).
            self.shutdown();
            let mut sched = self.inner.sched.lock().unwrap();
            let ticket = sched.next_ticket;
            sched.next_ticket += 1;
            // No job-table slot: the ack is not cancellable work.
            sched.queue.push_back(Queued {
                req,
                est: 0,
                reply: reply.clone(),
                ticket,
                token: CancelToken::none(),
                stream: false,
            });
            self.inner.work.notify_all();
            return;
        }
        match self.admit(&req) {
            Ok(est) => {
                let stream = matches!(&req.op, Op::Job(j) if j.stream);
                let token = CancelToken::armed();
                let mut sched = self.inner.sched.lock().unwrap();
                let ticket = sched.next_ticket;
                sched.next_ticket += 1;
                sched.jobs.push(JobSlot {
                    ticket,
                    id,
                    op,
                    state: JobState::Queued,
                    stream,
                    token: token.clone(),
                });
                sched.queue.push_back(Queued {
                    req,
                    est,
                    reply: reply.clone(),
                    ticket,
                    token,
                    stream,
                });
                self.inner.work.notify_all();
            }
            Err(resp) => {
                self.inner.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(ServerLine::Done(resp));
            }
        }
    }

    /// Submit and synchronously wait for the terminal response, discarding
    /// any progress lines (tests, examples, and the batch driver).
    pub fn request(&self, req: Request) -> Response {
        let (tx, rx) = mpsc::channel();
        self.submit(req, &tx);
        drop(tx);
        for line in rx {
            if let ServerLine::Done(resp) = line {
                return resp;
            }
        }
        panic!("engine always responds")
    }

    /// Handle a `cancel` op against request id `target`: reap its queued
    /// instances (each answers `cancelled` on its own connection, having
    /// reserved nothing — reservation happens at claim), flag the tokens of
    /// its running instances (they answer `cancelled` from their worker at
    /// the next poll, releasing their reservation through the normal
    /// epilogue). Finished or unknown ids are a structured `not_found`.
    fn cancel_job(&self, id: u64, target: u64) -> Response {
        let mut sched = self.inner.sched.lock().unwrap();
        let mut dequeued = 0usize;
        let mut signalled = 0usize;
        let queue = std::mem::take(&mut sched.queue);
        for q in queue {
            let cancellable = q.req.id == target && !matches!(q.req.op, Op::Shutdown);
            if !cancellable {
                sched.queue.push_back(q);
                continue;
            }
            if let Op::Load(l) = &q.req.op {
                // The load will never run; drop its submit-time shape
                // record so it cannot keep admitting doomed jobs.
                self.inner.dims.lock().unwrap().remove(&l.name);
            }
            sched.jobs.retain(|s| s.ticket != q.ticket);
            self.inner.cancelled.fetch_add(1, Ordering::Relaxed);
            dequeued += 1;
            let _ = q.reply.send(ServerLine::Done(Response::err(
                q.req.id,
                q.req.op_name(),
                ErrKind::Cancelled,
                "cancelled while queued",
            )));
        }
        for slot in sched.jobs.iter_mut() {
            if slot.id == target && slot.state == JobState::Running {
                slot.token.cancel();
                slot.state = JobState::Cancelled;
                signalled += 1;
            }
        }
        self.inner.work.notify_all();
        drop(sched);
        if dequeued + signalled == 0 {
            return Response::err(
                id,
                "cancel",
                ErrKind::NotFound,
                format!("no queued or running job with id {target}"),
            );
        }
        Response::ok(
            id,
            "cancel",
            Json::obj(vec![
                ("job", Json::num(target as f64)),
                ("dequeued", Json::num(dequeued as f64)),
                ("signalled", Json::num(signalled as f64)),
            ]),
        )
    }

    /// Submit-time admission: estimate the job's peak bytes and reject it
    /// when it could never run, even on an empty registry.
    fn admit(&self, req: &Request) -> Result<usize, Response> {
        let (op, id) = (req.op_name(), req.id);
        let limit = self.inner.budget.limit();
        let threads = self.inner.base.threads.max(self.inner.base.cd_threads);
        match &req.op {
            // Cancel never reaches admit (handled synchronously at submit);
            // save/export only clone an already-budgeted cached model.
            Op::Stat { .. } | Op::Evict { .. } | Op::Cancel { .. } | Op::Save(_)
            | Op::Export { .. } | Op::Shutdown => Ok(0),
            Op::Append(a) => {
                // The rows must land on a resident (or pending-load) name.
                if self.job_dims(&a.dataset).is_none() {
                    return Err(Response::err(
                        id,
                        op,
                        ErrKind::NotFound,
                        format!("dataset '{}' is not loaded", a.dataset),
                    ));
                }
                let est = match &a.path {
                    Some(path) => {
                        match coordinator::peek_dataset_dims(std::path::Path::new(path)) {
                            Ok((p, q, n)) => data_bytes(p, q, n),
                            Err(e) => {
                                return Err(Response::err(
                                    id,
                                    op,
                                    ErrKind::Io,
                                    format!("cannot read {path}: {e}"),
                                ))
                            }
                        }
                    }
                    None => a.rows.iter().map(|(x, y)| 8 * (x.len() + y.len())).sum(),
                };
                if est > limit {
                    return Err(Response::err(
                        id,
                        op,
                        ErrKind::Budget,
                        format!(
                            "appending to '{}' needs ~{} but the serve budget is {}",
                            a.dataset,
                            fmt_bytes(est),
                            fmt_bytes(limit)
                        ),
                    ));
                }
                Ok(est)
            }
            Op::Load(l) => {
                let (p, q, n) = match &l.source {
                    LoadSource::Generate { p, q, n, .. } => (*p, *q, *n),
                    LoadSource::Path(path) => {
                        match coordinator::peek_dataset_dims(std::path::Path::new(path)) {
                            Ok(dims) => dims,
                            Err(e) => {
                                return Err(Response::err(
                                    id,
                                    op,
                                    ErrKind::Io,
                                    format!("cannot read {path}: {e}"),
                                ))
                            }
                        }
                    }
                };
                let disk = l.storage.as_deref() == Some("disk");
                let est = if disk {
                    // Disk-backed: panels never exceed the configured cache
                    // cap, so only that (plus any eager warm stats) must fit.
                    let warm_cost = if l.warm {
                        stats_bytes(p, q) + NativeGemm::scratch_bytes_bound(threads)
                    } else {
                        0
                    };
                    data_bytes(p, q, n).min(self.inner.base.panel_cache) + warm_cost
                } else {
                    load_estimate(p, q, n, l.warm, threads)
                };
                if est > limit {
                    return Err(Response::err(
                        id,
                        op,
                        ErrKind::Budget,
                        format!(
                            "loading '{}' needs ~{} but the serve budget is {}",
                            l.name,
                            fmt_bytes(est),
                            fmt_bytes(limit)
                        ),
                    ));
                }
                self.inner.dims.lock().unwrap().insert(
                    l.name.clone(),
                    Dims {
                        p,
                        q,
                        n,
                        warm: l.warm,
                        disk,
                    },
                );
                Ok(est)
            }
            Op::Job(job) => {
                let cfg = job_config(&self.inner.base, job)
                    .map_err(|e| Response::err(id, op, ErrKind::Parse, e))?;
                let dims = self.job_dims(&job.dataset).ok_or_else(|| {
                    Response::err(
                        id,
                        op,
                        ErrKind::NotFound,
                        format!("dataset '{}' is not loaded", job.dataset),
                    )
                })?;
                let est = self.job_estimate(job.kind, &cfg, dims);
                // The bytes that must be resident for this job to run at
                // all: its own dataset plus the estimate. If that exceeds
                // the cap with everything else evicted, fail now.
                let floor = self.resident_bytes(dims).saturating_add(est);
                if floor > limit {
                    return Err(Response::err(
                        id,
                        op,
                        ErrKind::Budget,
                        format!(
                            "{} on '{}' needs ~{} (with its dataset resident) but the \
                             serve budget is {}",
                            job.kind.name(),
                            job.dataset,
                            fmt_bytes(floor),
                            fmt_bytes(limit)
                        ),
                    ));
                }
                Ok(est)
            }
        }
    }

    /// Shape knowledge for a job's dataset: the registry entry if resident,
    /// else the submit-time record of a pending load.
    fn job_dims(&self, dataset: &str) -> Option<Dims> {
        if let Some(e) = self.inner.registry.lock().unwrap().peek(dataset) {
            let warm = e.stat_computes >= 3;
            return Some(Dims {
                p: e.p,
                q: e.q,
                n: e.n,
                warm,
                disk: e.storage == "disk",
            });
        }
        self.inner.dims.lock().unwrap().get(dataset).copied()
    }

    /// Bytes a job's dataset keeps resident: the dense arrays, or the
    /// panel-cache ceiling when the dataset is disk-backed (panels above
    /// the cap degrade to transients instead of allocating).
    fn resident_bytes(&self, dims: Dims) -> usize {
        let dense = data_bytes(dims.p, dims.q, dims.n);
        if dims.disk {
            dense.min(self.inner.base.panel_cache)
        } else {
            dense
        }
    }

    fn job_estimate(&self, kind: JobKind, cfg: &RunConfig, dims: Dims) -> usize {
        let threads = cfg.threads.max(cfg.cd_threads).max(1);
        let solver = cfg.solver;
        let per_fit = fit_estimate(solver, dims.p, dims.q, threads);
        // A cold entry materializes its dense statistics during the job
        // (except the block solver, whose memory story never forms them —
        // under tiled statistics it instead needs the tile layer's resident
        // floor; the LRU cache above the floor only consumes budget that is
        // actually free).
        let stat_mode =
            StatMode::parse(&cfg.stat_mode, cfg.stat_tile).unwrap_or_default();
        let cold_stats = if dims.warm {
            0
        } else if solver == SolverKind::AltNewtonBcd {
            match stat_mode {
                StatMode::Tiled(t) => tiled_stats_floor(t, dims.p, dims.q, dims.n),
                StatMode::Dense => 0,
            }
        } else {
            stats_bytes(dims.p, dims.q)
        };
        match kind {
            JobKind::Fit | JobKind::Path => per_fit + cold_stats,
            // A refit briefly holds the old and the slid window at once
            // (the swap is copy-then-replace, never in-place mutation), so
            // reserve a second copy of the raw data on top of the fit. For a
            // disk-backed window the clone shares the backing store, so the
            // second copy costs at most the panel-cache ceiling.
            JobKind::Refit => per_fit + cold_stats + self.resident_bytes(dims),
            JobKind::Cv => {
                // Folds run on `cv_threads` parallel contexts over their own
                // (K-1)/K-sized data copies, plus the full-data refit.
                let fold = per_fit + stats_bytes(dims.p, dims.q)
                    + data_bytes(dims.p, dims.q, dims.n);
                cfg.cv_threads.max(1) * fold + per_fit + cold_stats
            }
        }
    }

    /// Stop accepting work; queued jobs still drain.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::Relaxed);
        let mut sched = self.inner.sched.lock().unwrap();
        sched.shutdown = true;
        self.inner.work.notify_all();
    }

    /// Block until the queue is empty and no job is running.
    pub fn drain(&self) {
        let mut sched = self.inner.sched.lock().unwrap();
        while !(sched.queue.is_empty() && sched.running == 0) {
            sched = self.inner.work.wait(sched).unwrap();
        }
    }

    /// Shut down and join the workers (drains the queue first).
    pub fn join(mut self) {
        self.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Layer job params over the serving base config through the config-file
/// schema (same keys, same errors).
fn job_config(base: &RunConfig, job: &JobOp) -> Result<RunConfig, String> {
    let mut cfg = base.clone();
    for (key, val) in &job.params {
        if FORBIDDEN_JOB_KEYS.contains(&key.as_str()) {
            return Err(format!("key '{key}' is not allowed in serve jobs"));
        }
        cfg.apply(key, val).map_err(|e| e.to_string())?;
    }
    Ok(cfg)
}

// ------------------------------------------------------------------ worker

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let job = claim(&inner);
        let Some(job) = job else { return };
        let _pool = inner.pool.as_ref().map(TeamPool::install);
        // A panicking solver must not take the worker (and the whole
        // session) down with it.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute(&inner, &job)
        }));
        let resp = outcome.unwrap_or_else(|_| {
            Response::err(
                job.req.id,
                job.req.op_name(),
                ErrKind::Solve,
                "job panicked; see server logs",
            )
        });
        if resp.is_ok() {
            inner.completed.fetch_add(1, Ordering::Relaxed);
        } else if resp.err_kind() == Some(ErrKind::Cancelled) {
            // A job stopped by its own token is neither success nor
            // failure; it has its own counter (and released its budget
            // transients on unwind like any early return).
            inner.cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            inner.failed.fetch_add(1, Ordering::Relaxed);
        }
        let _ = job.reply.send(ServerLine::Done(resp));
        if let Op::Load(l) = &job.req.op {
            // The submit-time shape record exists only to size jobs queued
            // behind an in-flight load; once the load completes (either
            // way) the registry is the sole source of truth, so drop it —
            // otherwise a failed or later-evicted dataset would keep
            // admitting doomed jobs through the stale record.
            inner.dims.lock().unwrap().remove(&l.name);
        }
        let mut sched = inner.sched.lock().unwrap();
        if let Op::Load(l) = &job.req.op {
            sched.active_loads.remove(&l.name);
        }
        sched.jobs.retain(|s| s.ticket != job.ticket);
        sched.reserved -= job.est;
        sched.running -= 1;
        inner.work.notify_all();
    }
}

/// Claim the next admissible job (head-of-line, FIFO). Returns `None` on
/// shutdown with an empty queue.
fn claim(inner: &Inner) -> Option<Queued> {
    let mut sched = inner.sched.lock().unwrap();
    loop {
        if let Some(head) = sched.queue.front() {
            // Sequencing: any head job touching a dataset whose load is
            // executing waits for it (see `Sched::active_loads`).
            let waiting_on_load = head
                .req
                .dataset_name()
                .is_some_and(|d| sched.active_loads.contains(d));
            let est = head.est;
            let admissible = inner
                .budget
                .live()
                .saturating_add(sched.reserved)
                .saturating_add(est)
                <= inner.budget.limit();
            if !waiting_on_load {
                if admissible {
                    let job = sched.queue.pop_front().unwrap();
                    if let Op::Load(l) = &job.req.op {
                        sched.active_loads.insert(l.name.clone());
                    }
                    if let Some(slot) =
                        sched.jobs.iter_mut().find(|s| s.ticket == job.ticket)
                    {
                        slot.state = JobState::Running;
                    }
                    sched.reserved += job.est;
                    sched.running += 1;
                    return Some(job);
                }
                if sched.running == 0 {
                    // Alone and still over: make room by evicting idle
                    // datasets (keeping the job's own), or fail the job.
                    let keep = head.req.dataset_name().map(str::to_string);
                    let fits = inner
                        .registry
                        .lock()
                        .unwrap()
                        .ensure_room(est, keep.as_deref());
                    if fits {
                        continue;
                    }
                    let job = sched.queue.pop_front().unwrap();
                    sched.jobs.retain(|s| s.ticket != job.ticket);
                    inner.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(ServerLine::Done(Response::err(
                        job.req.id,
                        job.req.op_name(),
                        ErrKind::Budget,
                        format!(
                            "job needs ~{} but only {} of the {} serve budget can be \
                             freed",
                            fmt_bytes(est),
                            fmt_bytes(inner.budget.available()),
                            fmt_bytes(inner.budget.limit())
                        ),
                    )));
                    inner.work.notify_all();
                    continue;
                }
            }
        } else if sched.shutdown {
            return None;
        }
        sched = inner.work.wait(sched).unwrap();
    }
}

// --------------------------------------------------------------- execution

fn execute(inner: &Inner, queued: &Queued) -> Response {
    let req = &queued.req;
    let (id, op) = (req.id, req.op_name());
    match &req.op {
        Op::Load(load) => execute_load(inner, id, load),
        Op::Job(job) => {
            execute_job(inner, id, job, &queued.token, queued.stream, &queued.reply)
        }
        Op::Append(append) => execute_append(inner, id, append),
        Op::Stat { dataset } => execute_stat(inner, id, dataset.as_deref()),
        Op::Evict { dataset } => match inner.registry.lock().unwrap().evict(dataset) {
            Ok(freed) => Response::ok(
                id,
                op,
                Json::obj(vec![
                    ("dataset", Json::str(dataset.clone())),
                    ("freed_bytes", Json::num(freed as f64)),
                ]),
            ),
            Err(e) => Response::err(id, op, registry_err_kind(&e), e.to_string()),
        },
        Op::Save(save) => execute_save(inner, id, save),
        Op::Export { dataset, solver } => {
            execute_export(inner, id, dataset, solver.as_deref())
        }
        // Cancel is handled synchronously at submit and never queued.
        Op::Cancel { .. } => Response::err(
            id,
            op,
            ErrKind::Parse,
            "cancel is handled at submit; it cannot be queued",
        ),
        // The flag was set at submit; this queued ack just keeps response
        // order FIFO behind the work that was already pending.
        Op::Shutdown => Response::ok(id, op, Json::obj(vec![])),
    }
}

fn registry_err_kind(e: &RegistryError) -> ErrKind {
    match e {
        RegistryError::NotFound(_) => ErrKind::NotFound,
        RegistryError::Busy(_) => ErrKind::Busy,
        RegistryError::Budget(_) => ErrKind::Budget,
    }
}

fn solve_err_kind(e: &SolveError) -> ErrKind {
    match e {
        SolveError::Budget(_) => ErrKind::Budget,
        SolveError::Checkpoint(_) => ErrKind::Io,
        SolveError::Cancelled => ErrKind::Cancelled,
        _ => ErrKind::Solve,
    }
}

/// Accept both the CLI spellings (`alt`, `bcd`, …) and the canonical
/// [`SolverKind::name`] form that model files and `stat` report.
fn parse_solver(s: &str) -> Option<SolverKind> {
    SolverKind::parse(s).or_else(|| SolverKind::all().into_iter().find(|k| k.name() == s))
}

fn execute_load(inner: &Inner, id: u64, load: &LoadOp) -> Response {
    let sw = Stopwatch::start();
    let op = "load";
    // Idempotent: a resident name is a registry hit, optionally re-warmed
    // (and, with a `model` key, re-seeded — the file governs either way).
    {
        let mut reg = inner.registry.lock().unwrap();
        if reg.contains(&load.name) {
            let warm = reg.lookup(&load.name).expect("checked resident");
            drop(reg);
            let mut guard = warm.lock().unwrap();
            if load.warm {
                if let Err(e) = guard.warm_stats() {
                    return Response::err(id, op, ErrKind::Budget, e.to_string());
                }
            }
            let seeded = match &load.model {
                Some(path) => match seed_model_from_file(inner, id, &mut guard, path) {
                    Ok(s) => Some(s),
                    Err(resp) => return resp,
                },
                None => None,
            };
            return Response::ok(
                id,
                op,
                load_result(&load.name, &guard, true, sw.seconds(), seeded.as_ref()),
            );
        }
    }
    let disk = load.storage.as_deref() == Some("disk");
    let data = match &load.source {
        LoadSource::Path(path) if disk => {
            // Bind the panel file out-of-core: only the shard table and up
            // to `panel_cache` bytes of panels ever become resident.
            match Dataset::open_disk(
                std::path::Path::new(path),
                inner.base.panel_rows,
                inner.base.panel_cache,
            ) {
                Ok(d) => d,
                Err(e) => {
                    return Response::err(
                        id,
                        op,
                        ErrKind::Io,
                        format!("cannot open {path} disk-backed: {e}"),
                    )
                }
            }
        }
        LoadSource::Path(path) => {
            match coordinator::load_dataset(std::path::Path::new(path)) {
                Ok(d) => d,
                Err(e) => {
                    return Response::err(
                        id,
                        op,
                        ErrKind::Io,
                        format!("cannot load {path}: {e}"),
                    )
                }
            }
        }
        LoadSource::Generate {
            workload,
            p,
            q,
            n,
            seed,
        } => coordinator::generate_problem(*workload, *p, *q, *n, *seed).data,
    };
    let (p, q, n) = (data.p(), data.q(), data.n());
    // Make room for the bytes the entry will pin, then build the warm
    // context *outside* the registry lock (warming runs Gram products).
    // A disk-backed entry pins its shard-table overhead plus at most the
    // panel-cache cap; a resident one pins the dense arrays.
    let resident = if data.is_disk() {
        data.bytes() + data_bytes(p, q, n).min(inner.base.panel_cache)
    } else {
        data_bytes(p, q, n)
    };
    let pin = resident + if load.warm { stats_bytes(p, q) } else { 0 };
    {
        let mut reg = inner.registry.lock().unwrap();
        if !reg.ensure_room(pin, None) {
            return Response::err(
                id,
                op,
                ErrKind::Budget,
                format!(
                    "'{}' needs {} resident but only {} of the {} serve budget can \
                     be freed",
                    load.name,
                    fmt_bytes(pin),
                    fmt_bytes(reg.budget().available()),
                    fmt_bytes(reg.budget().limit())
                ),
            );
        }
    }
    // Cached panels register against the shared budget, so `peak()` covers
    // out-of-core reads too (and the cap stays a real cap).
    data.bind_panel_budget(&inner.budget);
    let mut opts = inner.base.solve_options();
    opts.budget = inner.budget.clone();
    let mut warm = match WarmContext::new(Arc::new(data), inner.gemm.clone(), &opts) {
        Ok(w) => w,
        Err(e) => return Response::err(id, op, ErrKind::Budget, e.to_string()),
    };
    if load.warm {
        if let Err(e) = warm.warm_stats() {
            return Response::err(id, op, ErrKind::Budget, e.to_string());
        }
    }
    let seeded = match &load.model {
        Some(path) => match seed_model_from_file(inner, id, &mut warm, path) {
            Ok(s) => Some(s),
            Err(resp) => return resp,
        },
        None => None,
    };
    let result = load_result(&load.name, &warm, false, sw.seconds(), seeded.as_ref());
    match inner.registry.lock().unwrap().insert(&load.name, warm) {
        Ok(()) => Response::ok(id, op, result),
        Err(e) => Response::err(id, op, registry_err_kind(&e), e.to_string()),
    }
}

/// Seed a warm context's model cache from a model file written by `save`
/// (`load`'s optional `model` key — the warm-start-from-file path). The
/// file's solver must be known and its shape must match the dataset; the
/// operator asked for the seed explicitly, so failures are structured
/// errors rather than silent cold starts.
fn seed_model_from_file(
    inner: &Inner,
    id: u64,
    warm: &mut WarmContext,
    path: &str,
) -> Result<(SolverKind, (f64, f64)), Response> {
    let op = "load";
    let mf = checkpoint::load_model(std::path::Path::new(path))
        .map_err(|e| Response::err(id, op, ErrKind::Io, format!("cannot load model {path}: {e}")))?;
    let kind = parse_solver(&mf.solver).ok_or_else(|| {
        Response::err(
            id,
            op,
            ErrKind::Parse,
            format!("model file {path} names unknown solver '{}'", mf.solver),
        )
    })?;
    let data = warm.data();
    if (mf.p, mf.q) != (data.p(), data.q()) {
        return Err(Response::err(
            id,
            op,
            ErrKind::Parse,
            format!(
                "model file {path} is for a {}×{} problem but the dataset is {}×{}",
                mf.p,
                mf.q,
                data.p(),
                data.q()
            ),
        ));
    }
    if !warm.store_model(kind, mf.model, mf.lam, &inner.budget) {
        return Err(Response::err(
            id,
            op,
            ErrKind::Budget,
            format!("serve budget cannot hold the model from {path}"),
        ));
    }
    Ok((kind, mf.lam))
}

fn load_result(
    name: &str,
    warm: &WarmContext,
    already: bool,
    seconds: f64,
    seeded: Option<&(SolverKind, (f64, f64))>,
) -> Json {
    let data = warm.data();
    Json::obj(vec![
        ("name", Json::str(name)),
        ("p", Json::num(data.p() as f64)),
        ("q", Json::num(data.q() as f64)),
        ("n", Json::num(data.n() as f64)),
        ("storage", Json::str(data.storage_name())),
        ("already_loaded", Json::Bool(already)),
        ("pinned_bytes", Json::num(warm.pinned_bytes() as f64)),
        ("stat_computes", Json::num(warm.stat_computes() as f64)),
        ("model_loaded", Json::Bool(seeded.is_some())),
        (
            "model_solver",
            seeded
                .map(|(k, _)| Json::str(k.name()))
                .unwrap_or(Json::Null),
        ),
        (
            "model_lambda_l",
            seeded.map(|(_, lam)| Json::num(lam.0)).unwrap_or(Json::Null),
        ),
        (
            "model_lambda_t",
            seeded.map(|(_, lam)| Json::num(lam.1)).unwrap_or(Json::Null),
        ),
        ("seconds", Json::num(seconds)),
    ])
}

/// Resolve the cached model `save`/`export` operate on: the named dataset's
/// warm entry, the requested (or default) solver's cached model, cloned out
/// so the entry lock is held only for the copy.
fn cached_model_for(
    inner: &Inner,
    id: u64,
    op: &str,
    dataset: &str,
    solver: Option<&str>,
) -> Result<(SolverKind, (f64, f64), CggmModel, usize, usize), Response> {
    let kind = match solver {
        None => inner.base.solver,
        Some(s) => parse_solver(s).ok_or_else(|| {
            Response::err(id, op, ErrKind::Parse, format!("unknown solver '{s}'"))
        })?,
    };
    let entry = inner
        .registry
        .lock()
        .unwrap()
        .lookup(dataset)
        .ok_or_else(|| {
            Response::err(
                id,
                op,
                ErrKind::NotFound,
                format!("dataset '{dataset}' is not loaded"),
            )
        })?;
    let warm = entry.lock().unwrap();
    let model = warm.cached_model(kind).cloned().ok_or_else(|| {
        Response::err(
            id,
            op,
            ErrKind::NotFound,
            format!(
                "no cached {} model for '{dataset}' — run a fit first",
                kind.name()
            ),
        )
    })?;
    let lam = warm.cached_lambda(kind).unwrap_or((f64::NAN, f64::NAN));
    let data = warm.data();
    Ok((kind, lam, model, data.p(), data.q()))
}

fn execute_save(inner: &Inner, id: u64, save: &SaveOp) -> Response {
    let op = "save";
    let (kind, lam, model, p, q) =
        match cached_model_for(inner, id, op, &save.dataset, save.solver.as_deref()) {
            Ok(found) => found,
            Err(resp) => return resp,
        };
    match checkpoint::save_model(std::path::Path::new(&save.path), kind.name(), lam, &model) {
        Ok(()) => Response::ok(
            id,
            op,
            Json::obj(vec![
                ("dataset", Json::str(save.dataset.clone())),
                ("solver", Json::str(kind.name())),
                ("path", Json::str(save.path.clone())),
                ("p", Json::num(p as f64)),
                ("q", Json::num(q as f64)),
                ("lambda_l", Json::num(lam.0)),
                ("lambda_t", Json::num(lam.1)),
            ]),
        ),
        Err(e) => Response::err(
            id,
            op,
            ErrKind::Io,
            format!("cannot write {}: {e}", save.path),
        ),
    }
}

fn execute_export(inner: &Inner, id: u64, dataset: &str, solver: Option<&str>) -> Response {
    let op = "export";
    match cached_model_for(inner, id, op, dataset, solver) {
        Ok((kind, lam, model, p, q)) => Response::ok(
            id,
            op,
            Json::obj(vec![
                ("dataset", Json::str(dataset)),
                ("solver", Json::str(kind.name())),
                ("p", Json::num(p as f64)),
                ("q", Json::num(q as f64)),
                ("lambda_l", Json::num(lam.0)),
                ("lambda_t", Json::num(lam.1)),
                ("model", checkpoint::model_to_json(&model)),
            ]),
        ),
        Err(resp) => resp,
    }
}

/// Accept `append` rows against a resident entry: validate shapes, buffer
/// them (budget-tracked) for the next `refit`. Rows come inline from the
/// request (finiteness parse-enforced) or from a dataset file, which gets
/// the same shape/finiteness validation here.
fn execute_append(inner: &Inner, id: u64, append: &AppendOp) -> Response {
    let op = "append";
    let entry = match inner.registry.lock().unwrap().lookup(&append.dataset) {
        Some(e) => e,
        None => {
            return Response::err(
                id,
                op,
                ErrKind::NotFound,
                format!("dataset '{}' is not loaded", append.dataset),
            )
        }
    };
    let mut warm = entry.lock().unwrap();
    let data = warm.data();
    let (p, q) = (data.p(), data.q());
    let rows: Vec<(Vec<f64>, Vec<f64>)> = match &append.path {
        Some(path) => {
            let d = match coordinator::load_dataset(std::path::Path::new(path)) {
                Ok(d) => d,
                Err(e) => {
                    return Response::err(
                        id,
                        op,
                        ErrKind::Io,
                        format!("cannot load {path}: {e}"),
                    )
                }
            };
            if (d.p(), d.q()) != (p, q) {
                return Response::err(
                    id,
                    op,
                    ErrKind::Parse,
                    format!(
                        "samples in {path} have p={}, q={} but '{}' has p={p}, q={q}",
                        d.p(),
                        d.q(),
                        append.dataset
                    ),
                );
            }
            (0..d.n())
                .map(|s| {
                    let mut x = vec![0.0; p];
                    let mut y = vec![0.0; q];
                    d.x_col_into(s, &mut x);
                    d.y_col_into(s, &mut y);
                    (x, y)
                })
                .collect()
        }
        None => append.rows.clone(),
    };
    for (idx, (x, y)) in rows.iter().enumerate() {
        if x.len() != p || y.len() != q {
            return Response::err(
                id,
                op,
                ErrKind::Parse,
                format!(
                    "row {idx} has {} x-values and {} y-values but '{}' has p={p}, q={q}",
                    x.len(),
                    y.len(),
                    append.dataset
                ),
            );
        }
        if !x.iter().chain(y.iter()).all(|v| v.is_finite()) {
            return Response::err(
                id,
                op,
                ErrKind::Parse,
                format!("row {idx} contains a non-finite value"),
            );
        }
    }
    let accepted = rows.len();
    let pending = match warm.push_pending(rows, &inner.budget) {
        Ok(total) => total,
        Err(e) => return Response::err(id, op, ErrKind::Budget, e.to_string()),
    };
    let (n, pinned) = (warm.data().n(), warm.pinned_bytes());
    drop(warm);
    inner.registry.lock().unwrap().refresh(&append.dataset, |e| {
        e.pending = pending;
        e.pinned_bytes = pinned;
    });
    Response::ok(
        id,
        op,
        Json::obj(vec![
            ("dataset", Json::str(append.dataset.clone())),
            ("accepted", Json::num(accepted as f64)),
            ("pending", Json::num(pending as f64)),
            ("n", Json::num(n as f64)),
            ("pinned_bytes", Json::num(pinned as f64)),
        ]),
    )
}

/// Post-job entry-counter snapshot, taken under the entry lock and applied
/// to the registry's [`Entry`](super::registry::Entry) in the epilogue so
/// `stat` never waits behind a running solve.
struct EntrySnap {
    pinned: usize,
    tiles: Option<TileStats>,
    /// Statistics materialized from scratch *by this job*.
    stat_delta: usize,
    warm_reused: bool,
    n: usize,
    appended: usize,
    evicted: usize,
    pending: usize,
    /// Cumulative in-place statistic corrections (carried across window
    /// rebuilds, so a snapshot — not an increment).
    stat_updates: usize,
    /// Panel-cache counters (disk-backed entries; cumulative on the store).
    panels: Option<crate::storage::PanelStats>,
}

fn entry_snap(warm: &WarmContext, stat_delta: usize, warm_reused: bool) -> EntrySnap {
    EntrySnap {
        pinned: warm.pinned_bytes(),
        tiles: warm.tile_stats(),
        stat_delta,
        warm_reused,
        n: warm.data().n(),
        appended: warm.appended(),
        evicted: warm.evicted(),
        pending: warm.pending_rows(),
        stat_updates: warm.stat_updates(),
        panels: warm.data().panel_stats(),
    }
}

fn execute_job(
    inner: &Inner,
    id: u64,
    job: &JobOp,
    token: &CancelToken,
    stream: bool,
    reply: &mpsc::Sender<ServerLine>,
) -> Response {
    let op = job.kind.name();
    let cfg = match job_config(&inner.base, job) {
        Ok(cfg) => cfg,
        Err(e) => return Response::err(id, op, ErrKind::Parse, e),
    };
    let kind = cfg.solver;
    let entry = match inner.registry.lock().unwrap().lookup(&job.dataset) {
        Some(e) => e,
        None => {
            return Response::err(
                id,
                op,
                ErrKind::NotFound,
                format!("dataset '{}' is not loaded", job.dataset),
            )
        }
    };
    let mut opts = cfg.solve_options();
    opts.budget = inner.budget.clone();
    // The job-table slot shares this token; a `cancel` op flips it and the
    // solvers/path driver poll it at their wall-clock sites.
    opts.cancel = token.clone();
    let sw = Stopwatch::start();
    let outcome = match job.kind {
        JobKind::Fit => {
            let mut warm = entry.lock().unwrap();
            let before = warm.stat_computes();
            let seed_lambda = warm.cached_lambda(kind);
            let seed = if job.warm { warm.cached_model(kind) } else { None };
            let warm_reused = seed.is_some();
            match solve_in_context(kind, warm.ctx(), &opts, seed) {
                Ok(res) => {
                    let stat_delta = warm.stat_computes() - before;
                    let summary =
                        RunSummary::from_result(kind, &res, None, inner.budget.peak());
                    warm.store_model(
                        kind,
                        res.model,
                        (opts.lam_l, opts.lam_t),
                        &inner.budget,
                    );
                    let result = Json::obj(vec![
                        ("summary", summary.to_json()),
                        ("trace", res.trace.to_json()),
                        ("registry_hit", Json::Bool(true)),
                        ("warm_started", Json::Bool(res.trace.warm_started)),
                        ("warm_model_reused", Json::Bool(warm_reused)),
                        (
                            "warm_model_lambda",
                            seed_lambda
                                .filter(|_| warm_reused)
                                .map(|(l, _)| Json::num(l))
                                .unwrap_or(Json::Null),
                        ),
                        ("stat_computes", Json::num(stat_delta as f64)),
                        ("seconds", Json::num(sw.seconds())),
                    ]);
                    Ok((result, entry_snap(&warm, stat_delta, warm_reused)))
                }
                Err(e) => Err(e),
            }
        }
        JobKind::Refit => {
            let mut warm = entry.lock().unwrap();
            let before = warm.stat_computes();
            let updates_before = warm.stat_updates();
            // Fold the buffered rows in and expire past the window cap —
            // on a *copy* of the data, swapped in by `rebuild` (the old
            // window is shared with in-flight readers and never mutated).
            let rows = warm.take_pending();
            let data = warm.data();
            let (p, q) = (data.p(), data.q());
            let mut next = (*data).clone();
            let mut delta = WindowDelta::new(next.n());
            if !rows.is_empty() {
                let k = rows.len();
                let xa = Mat::from_fn(p, k, |i, j| rows[j].0[i]);
                let ya = Mat::from_fn(q, k, |i, j| rows[j].1[i]);
                // Disk-backed windows append a shard pair to the panel
                // file; an I/O failure re-buffers the rows for a retry.
                if let Err(e) = next.append_samples(&xa, &ya) {
                    let _ = warm.push_pending(rows, &inner.budget);
                    return Response::err(
                        id,
                        op,
                        ErrKind::Io,
                        format!("cannot append to '{}': {e}", job.dataset),
                    );
                }
                delta.record_append(SampleBlock::new(xa, ya));
            }
            if let Some(cap) = job.window {
                if next.n() > cap {
                    match next.evict_oldest(next.n() - cap) {
                        Ok(block) => delta.record_evict(block),
                        Err(e) => {
                            return Response::err(
                                id,
                                op,
                                ErrKind::Io,
                                format!("cannot expire from '{}': {e}", job.dataset),
                            )
                        }
                    }
                }
            }
            let (folded, expired) = (delta.added_k(), delta.removed_k());
            if !delta.is_empty() {
                if let Err(e) = warm.rebuild(Arc::new(next), &delta, &opts) {
                    // The slid window did not fit; re-buffer the rows so a
                    // later refit (after an evict elsewhere) can retry.
                    let _ = warm.push_pending(rows, &inner.budget);
                    return Response::err(id, op, ErrKind::Budget, e.to_string());
                }
            }
            let seed_lambda = warm.cached_lambda(kind);
            let seed = if job.warm { warm.cached_model(kind) } else { None };
            let warm_reused = seed.is_some();
            match solve_in_context(kind, warm.ctx(), &opts, seed) {
                Ok(res) => {
                    let stat_delta = warm.stat_computes() - before;
                    let summary =
                        RunSummary::from_result(kind, &res, None, inner.budget.peak());
                    let trace = res.trace;
                    warm.store_model(
                        kind,
                        res.model,
                        (opts.lam_l, opts.lam_t),
                        &inner.budget,
                    );
                    let result = Json::obj(vec![
                        ("summary", summary.to_json()),
                        ("trace", trace.to_json()),
                        ("registry_hit", Json::Bool(true)),
                        ("warm_started", Json::Bool(trace.warm_started)),
                        ("warm_model_reused", Json::Bool(warm_reused)),
                        (
                            "warm_model_lambda",
                            seed_lambda
                                .filter(|_| warm_reused)
                                .map(|(l, _)| Json::num(l))
                                .unwrap_or(Json::Null),
                        ),
                        ("appended", Json::num(folded as f64)),
                        ("evicted", Json::num(expired as f64)),
                        ("n", Json::num(warm.data().n() as f64)),
                        ("stat_computes", Json::num(stat_delta as f64)),
                        (
                            "stat_updates",
                            Json::num((warm.stat_updates() - updates_before) as f64),
                        ),
                        ("seconds", Json::num(sw.seconds())),
                    ]);
                    Ok((result, entry_snap(&warm, stat_delta, warm_reused)))
                }
                Err(e) => Err(e),
            }
        }
        JobKind::Path => {
            let warm = entry.lock().unwrap();
            let before = warm.stat_computes();
            let popts = cfg.path_options(true);
            // Streamed progress rides the existing per-point observer; a
            // dropped client just makes `send` a no-op (the job finishes
            // and its terminal response is discarded with the channel).
            let observe = |k: usize, point: &coordinator::PathPoint, _: &CggmModel| {
                if !stream {
                    return;
                }
                let _ = reply.send(ServerLine::Progress(Progress {
                    id,
                    op: op.to_string(),
                    body: Json::obj(vec![
                        ("point", Json::num(k as f64)),
                        ("lambda_l", Json::num(point.lam_l)),
                        ("lambda_t", Json::num(point.lam_t)),
                        ("f", Json::num(point.f)),
                        ("lambda_nnz", Json::num(point.lambda_nnz as f64)),
                        ("theta_nnz", Json::num(point.theta_nnz as f64)),
                        ("converged", Json::Bool(point.converged)),
                        ("seconds", Json::num(point.seconds)),
                    ]),
                }));
            };
            match coordinator::fit_path_with(kind, warm.ctx(), &opts, &popts, observe) {
                Ok(path) => {
                    let stat_delta = warm.stat_computes() - before;
                    let result = Json::obj(vec![
                        ("path", path.to_json()),
                        ("registry_hit", Json::Bool(true)),
                        ("stat_computes", Json::num(stat_delta as f64)),
                        ("seconds", Json::num(sw.seconds())),
                    ]);
                    Ok((result, entry_snap(&warm, stat_delta, false)))
                }
                Err(e) => Err(e),
            }
        }
        JobKind::Cv => {
            // CV splits its own fold datasets/contexts; it needs the shared
            // data handle, not the warm context — so the entry lock is held
            // only long enough to clone the `Arc`.
            let data: Arc<Dataset> = entry.lock().unwrap().data();
            let popts = cfg.path_options(true);
            let mut cvo = cfg.cv_options();
            // K parallel folds must not interleave into one checkpoint
            // owned by some other client's run.
            cvo.checkpoint = None;
            cvo.resume = false;
            // Fold threads score points concurrently; the observer must be
            // Sync, so the (non-Sync) sender goes behind a mutex — one
            // short lock per scored point, same discipline as the CV
            // checkpoint writer.
            let tx = Mutex::new(reply.clone());
            let on_score = |f: usize, j: usize, x: f64| {
                if !stream {
                    return;
                }
                let _ = tx.lock().unwrap().send(ServerLine::Progress(Progress {
                    id,
                    op: op.to_string(),
                    body: Json::obj(vec![
                        ("fold", Json::num(f as f64)),
                        ("point", Json::num(j as f64)),
                        ("heldout_nll", Json::num(x)),
                    ]),
                }));
            };
            match coordinator::cross_validate_with(
                kind,
                &data,
                &opts,
                &popts,
                &cvo,
                inner.gemm.as_ref(),
                &on_score,
            ) {
                Ok(cv) => {
                    let result = Json::obj(vec![
                        ("cv", cv.to_json()),
                        ("registry_hit", Json::Bool(true)),
                        ("seconds", Json::num(sw.seconds())),
                    ]);
                    let guard = entry.lock().unwrap();
                    let snap = entry_snap(&guard, 0, false);
                    drop(guard);
                    Ok((result, snap))
                }
                Err(e) => Err(e),
            }
        }
    };
    match outcome {
        Ok((result, snap)) => {
            let mut reg = inner.registry.lock().unwrap();
            reg.refresh(&job.dataset, |e| {
                e.jobs += 1;
                if snap.warm_reused {
                    e.warm_reuses += 1;
                }
                e.stat_computes += snap.stat_delta;
                // The rest are cumulative on the context (or current-state
                // values), so snapshot — don't accumulate — mirrors
                // `pinned_bytes`.
                e.stat_updates = snap.stat_updates;
                e.n = snap.n;
                e.appended = snap.appended;
                e.evicted = snap.evicted;
                e.pending = snap.pending;
                e.tile_stats = snap.tiles;
                e.panel_stats = snap.panels;
                e.pinned_bytes = snap.pinned;
            });
            Response::ok(id, op, result)
        }
        Err(e) => Response::err(id, op, solve_err_kind(&e), e.to_string()),
    }
}

fn execute_stat(inner: &Inner, id: u64, dataset: Option<&str>) -> Response {
    let reg = inner.registry.lock().unwrap();
    if let Some(name) = dataset {
        if !reg.contains(name) {
            return Response::err(
                id,
                "stat",
                ErrKind::NotFound,
                format!("dataset '{name}' is not loaded"),
            );
        }
    }
    let datasets: Vec<Json> = reg
        .entries()
        .filter(|(name, _)| dataset.map(|d| d == name.as_str()).unwrap_or(true))
        .map(|(name, e)| {
            let ts = e.tile_stats.unwrap_or(TileStats::default());
            let ps = e.panel_stats.unwrap_or_default();
            // Cached-model names come from the entry lock; `try_lock` so a
            // running solve on the entry never stalls `stat` (a busy entry
            // just reports an empty list this round).
            let cached: Vec<Json> = e
                .warm
                .try_lock()
                .map(|g| g.cached_solvers().iter().map(|s| Json::str(*s)).collect())
                .unwrap_or_default();
            Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("p", Json::num(e.p as f64)),
                ("q", Json::num(e.q as f64)),
                ("n", Json::num(e.n as f64)),
                ("storage", Json::str(e.storage)),
                ("cached_models", Json::Arr(cached)),
                ("pinned_bytes", Json::num(e.pinned_bytes as f64)),
                ("stat_computes", Json::num(e.stat_computes as f64)),
                // Streaming-window observability: `n` above is current
                // occupancy; these are lifetime flow totals plus the
                // incremental-vs-rebuilt statistics work split. One full
                // rebuild recomputes `stat_bytes`; one incremental pass
                // corrects the same bytes in place with O(k·(p+q)²) flops.
                ("appended", Json::num(e.appended as f64)),
                ("evicted", Json::num(e.evicted as f64)),
                ("pending", Json::num(e.pending as f64)),
                ("stat_updates", Json::num(e.stat_updates as f64)),
                ("stat_bytes", Json::num(stats_bytes(e.p, e.q) as f64)),
                ("tile_hits", Json::num(ts.hits as f64)),
                ("tile_misses", Json::num(ts.misses as f64)),
                ("tile_evictions", Json::num(ts.evictions as f64)),
                ("tile_spills", Json::num(ts.spills as f64)),
                ("tiles_computed", Json::num(ts.computes as f64)),
                // Out-of-core panel traffic (all zero for `"mem"` entries):
                // cumulative on the backing store, shared by every clone.
                ("panel_reads", Json::num(ps.reads as f64)),
                ("panel_cache_hits", Json::num(ps.hits as f64)),
                ("panel_cache_misses", Json::num(ps.misses as f64)),
                ("panel_cache_evictions", Json::num(ps.evictions as f64)),
                ("panel_transient", Json::num(ps.transient as f64)),
                ("jobs", Json::num(e.jobs as f64)),
                ("warm_reuses", Json::num(e.warm_reuses as f64)),
                ("last_used", Json::num(e.last_used as f64)),
            ])
        })
        .collect();
    let registry = Json::obj(vec![
        ("hits", Json::num(reg.hits as f64)),
        ("misses", Json::num(reg.misses as f64)),
        ("evictions", Json::num(reg.evictions as f64)),
        ("pinned_bytes", Json::num(reg.pinned_bytes() as f64)),
        ("datasets", Json::Arr(datasets)),
    ]);
    drop(reg);
    let budget = &inner.budget;
    let limit = if budget.limit() == usize::MAX {
        Json::Null
    } else {
        Json::num(budget.limit() as f64)
    };
    let sched = inner.sched.lock().unwrap();
    // The job table makes `running` exact: Running slots whose op is not
    // `stat` (this very request holds a Running slot — excluding by op
    // replaces the old off-by-one `saturating_sub(1)` hack, which
    // undercounted whenever a *different* stat was in flight too).
    let running = sched
        .jobs
        .iter()
        .filter(|s| s.state == JobState::Running && s.op != "stat")
        .count();
    let stream_subscribers = sched.jobs.iter().filter(|s| s.stream).count();
    let states: Vec<Json> = sched
        .jobs
        .iter()
        .filter(|s| s.op != "stat")
        .map(|s| {
            Json::obj(vec![
                ("id", Json::num(s.id as f64)),
                ("op", Json::str(s.op)),
                ("state", Json::str(s.state.as_str())),
                ("stream", Json::Bool(s.stream)),
            ])
        })
        .collect();
    let jobs = Json::obj(vec![
        ("queued", Json::num(sched.queue.len() as f64)),
        ("running", Json::num(running as f64)),
        ("stream_subscribers", Json::num(stream_subscribers as f64)),
        ("states", Json::Arr(states)),
        (
            "completed",
            Json::num(inner.completed.load(Ordering::Relaxed) as f64),
        ),
        (
            "failed",
            Json::num(inner.failed.load(Ordering::Relaxed) as f64),
        ),
        (
            "rejected",
            Json::num(inner.rejected.load(Ordering::Relaxed) as f64),
        ),
        (
            "cancelled",
            Json::num(inner.cancelled.load(Ordering::Relaxed) as f64),
        ),
    ]);
    drop(sched);
    Response::ok(
        id,
        "stat",
        Json::obj(vec![
            (
                "budget",
                Json::obj(vec![
                    ("limit", limit),
                    ("live", Json::num(budget.live() as f64)),
                    ("peak", Json::num(budget.peak() as f64)),
                ]),
            ),
            ("jobs", jobs),
            ("registry", registry),
        ]),
    )
}
