//! The batch manifest driver: execute a file of serve-protocol jobs
//! through the same [`ServeEngine`] the daemon uses (`cggm batch FILE`).
//!
//! A manifest is either a bare JSON array of request objects or
//! `{"defaults": {...}, "jobs": [...]}` — see
//! [`crate::runtime::manifest::JobManifest`]. Offline sweeps and the
//! long-lived daemon thus share one code path: admission control, the warm
//! registry, per-dataset sequencing, and the worker pool behave
//! identically, so a manifest's results are the daemon's results.

use std::sync::mpsc;

use super::engine::ServeEngine;
use super::protocol::{Request, Response, ServerLine};
use crate::runtime::manifest::JobManifest;

/// Outcome of one manifest run: every response (ordered by request id,
/// parse failures included) plus the failure count.
pub struct BatchOutcome {
    pub responses: Vec<Response>,
    pub failures: usize,
}

impl BatchOutcome {
    /// JSONL rendering, one response per line (the `cggm batch` output).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for resp in &self.responses {
            out.push_str(&resp.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

/// Run every job of a parsed manifest. Jobs are submitted in manifest
/// order (FIFO — per-dataset sequencing holds), run with the engine's
/// configured concurrency, and reported ordered by id.
pub fn run_batch(engine: &ServeEngine, manifest: &JobManifest) -> BatchOutcome {
    let (tx, rx) = mpsc::channel::<ServerLine>();
    let mut parse_failures = Vec::new();
    for (k, job) in manifest.jobs().iter().enumerate() {
        match Request::parse(job) {
            Ok(req) => engine.submit(req, &tx),
            Err(e) => parse_failures.push(Response::err(
                (k + 1) as u64,
                "parse",
                super::protocol::ErrKind::Parse,
                e,
            )),
        }
    }
    drop(tx);
    // The channel closes when the last job's reply sender drops. Batch
    // mode never sets `stream:true`, but a manifest that does is still
    // well-defined: progress lines are dropped, terminals kept.
    let mut responses: Vec<Response> = rx
        .into_iter()
        .filter_map(|line| match line {
            ServerLine::Done(resp) => Some(resp),
            ServerLine::Progress(_) => None,
        })
        .collect();
    responses.extend(parse_failures);
    responses.sort_by_key(|r| r.id);
    let failures = responses.iter().filter(|r| !r.is_ok()).count();
    BatchOutcome {
        responses,
        failures,
    }
}
