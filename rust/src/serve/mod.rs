//! `cggm serve` — the long-lived multi-dataset serving runtime.
//!
//! The paper's point is that one machine can solve million-dimensional
//! CGGM problems; this subsystem is what lets one *process* keep doing so
//! under repeat traffic. Historically every `cggm fit` paid the full
//! dataset-read + Gram-statistics + coloring/clustering setup before the
//! first Newton step; `serve` keeps that state alive between jobs:
//!
//! - [`registry`] — named, long-lived warm [`SolverContext`]s (raw data,
//!   `S_yy`/`S_xx`/`S_xy`, clustering partitions, CD colorings, cached
//!   warm-start models), LRU-evicted against one shared
//!   [`MemBudget`](crate::util::membudget::MemBudget);
//! - [`engine`] — a bounded worker pool draining a FIFO queue of
//!   admission-controlled `fit` / `path` / `cv` / `load` / `evict` /
//!   `stat` jobs, with submit-time peak-bytes estimates from the memwall
//!   estimators and a persistent
//!   [`TeamPool`](crate::util::threadpool::TeamPool) shared across jobs;
//! - [`protocol`] — the JSONL request/response schema (job keys are config
//!   keys);
//! - [`batch`] — `cggm batch FILE`: a manifest of jobs through the same
//!   engine, so offline sweeps and the daemon share one code path.
//!
//! Transport is stdio by default ([`serve_connection`] on
//! stdin/stdout) or a unix socket (`--socket PATH`, [`serve_unix`]) —
//! connections come and go, the engine and its warm registry persist.
//! Socket mode is concurrent: each accepted connection gets its own
//! reader thread over the one shared engine, so a long `path` job on one
//! connection never blocks a `stat` probe or a `cancel` on another. Each
//! connection also gets its own writer thread, so streamed `progress`
//! lines and terminal responses from different connections never
//! interleave within a line.
//!
//! [`SolverContext`]: crate::solvers::SolverContext

pub mod batch;
pub mod engine;
pub mod protocol;
pub mod registry;

pub use batch::{run_batch, BatchOutcome};
pub use engine::ServeEngine;
pub use protocol::{
    AppendOp, ErrKind, Op, Progress, Request, Response, SaveOp, ServerLine, MAX_APPEND_ROWS,
};
pub use registry::{Registry, WarmContext};

use std::io::{BufRead, Write};
use std::sync::mpsc;

/// Hard cap on one request line, in bytes. A well-formed request is a few
/// hundred bytes; the cap bounds what one hostile client can make the
/// daemon buffer. An over-long line is answered with a `parse` error and
/// its remaining bytes are discarded — the connection itself survives.
pub const MAX_REQUEST_LINE_BYTES: usize = 1 << 20;

/// Outcome of one capped line read.
enum LineRead {
    /// Clean end of stream.
    Eof,
    /// A complete line (without the newline; trailing `\r` stripped).
    Line(String),
    /// The line exceeded the cap; its remainder was discarded.
    TooLong,
    /// The line was not valid UTF-8; it was discarded through its newline.
    NotUtf8,
    /// No bytes arrived within the stream's read timeout and nothing is
    /// buffered — the connection is merely quiet. Socket mode uses this to
    /// notice engine shutdown (triggered from *another* connection) without
    /// blocking forever in `read`.
    Idle,
}

/// Read one `\n`-terminated line, buffering at most `cap` bytes. Unlike
/// `BufRead::lines`, an over-long or non-UTF-8 line is a recoverable
/// per-line condition, not the end of the stream.
fn read_line_capped<R: BufRead>(reader: &mut R, cap: usize) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // A timeout mid-line keeps waiting for the rest of that
                // line; a timeout between lines reports Idle so the caller
                // can poll for shutdown.
                if buf.is_empty() {
                    return Ok(LineRead::Idle);
                }
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            // EOF: a non-empty unterminated tail still counts as a line.
            if buf.is_empty() {
                return Ok(LineRead::Eof);
            }
            break;
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                let over = buf.len() + nl > cap;
                if !over {
                    buf.extend_from_slice(&chunk[..nl]);
                }
                reader.consume(nl + 1);
                if over {
                    return Ok(LineRead::TooLong);
                }
                break;
            }
            None => {
                let over = buf.len() + chunk.len() > cap;
                if !over {
                    buf.extend_from_slice(chunk);
                }
                let n = chunk.len();
                reader.consume(n);
                if over {
                    discard_to_newline(reader)?;
                    return Ok(LineRead::TooLong);
                }
            }
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(LineRead::Line(s)),
        Err(_) => Ok(LineRead::NotUtf8),
    }
}

/// Consume input through the next `\n` (or EOF) without buffering it.
fn discard_to_newline<R: BufRead>(reader: &mut R) -> std::io::Result<()> {
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(());
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                reader.consume(nl + 1);
                return Ok(());
            }
            None => {
                let n = chunk.len();
                reader.consume(n);
            }
        }
    }
}

/// Serve one JSONL connection: requests read line-by-line from `reader`
/// (submitted in order), server lines — streamed `progress` lines and
/// terminal responses — written as they arrive by a writer thread.
/// Returns when the client disconnects (EOF), sends `{"op":"shutdown"}`,
/// or (socket mode) another connection shuts the engine down, after
/// draining this connection's in-flight jobs — the engine itself stays
/// alive across ordinary disconnects, with the registry still warm.
///
/// Per-line faults — malformed JSON, a line past
/// [`MAX_REQUEST_LINE_BYTES`], invalid UTF-8 — are answered with a
/// `parse`-kind error response and the session continues; only a transport
/// read error ends it.
pub fn serve_connection<R: BufRead, W: Write + Send>(
    engine: &ServeEngine,
    mut reader: R,
    writer: &mut W,
) -> std::io::Result<()> {
    let (tx, rx) = mpsc::channel::<ServerLine>();
    std::thread::scope(|scope| {
        let writer_thread = scope.spawn(move || -> std::io::Result<()> {
            for line in rx {
                writeln!(writer, "{}", line.to_json().to_string())?;
                writer.flush()?;
            }
            Ok(())
        });
        loop {
            let line = match read_line_capped(&mut reader, MAX_REQUEST_LINE_BYTES) {
                Ok(LineRead::Eof) => break,
                Ok(LineRead::Line(line)) => line,
                Ok(LineRead::Idle) => {
                    if engine.is_shutdown() {
                        break;
                    }
                    continue;
                }
                Ok(LineRead::TooLong) => {
                    let _ = tx.send(ServerLine::Done(Response::err(
                        0,
                        "parse",
                        ErrKind::Parse,
                        format!("request line exceeds {MAX_REQUEST_LINE_BYTES} bytes"),
                    )));
                    continue;
                }
                Ok(LineRead::NotUtf8) => {
                    let _ = tx.send(ServerLine::Done(Response::err(
                        0,
                        "parse",
                        ErrKind::Parse,
                        "request line is not valid UTF-8",
                    )));
                    continue;
                }
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            match Request::parse_line(&line) {
                Ok(req) => {
                    let is_shutdown = matches!(req.op, Op::Shutdown);
                    engine.submit(req, &tx);
                    if is_shutdown {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(ServerLine::Done(Response::err(0, "parse", ErrKind::Parse, e)));
                }
            }
        }
        // Every queued job holds a reply sender clone; once this
        // connection's jobs finish and this original drops, the writer's
        // channel closes. Draining the whole engine here would make one
        // client's disconnect wait on every other client's queue, so the
        // writer join — which waits on exactly this connection's jobs —
        // is the synchronization point.
        drop(tx);
        if engine.is_shutdown() {
            engine.drain();
        }
        writer_thread.join().expect("writer thread panicked")
    })
}

/// Serve JSONL connections on a unix socket, **concurrently** — one
/// reader thread per accepted connection over the shared engine — until a
/// client sends `{"op":"shutdown"}`. The warm registry persists across
/// connections — that is the whole point — and a long job on one
/// connection never blocks `stat`/`cancel` traffic on another.
///
/// Mechanics: the listener runs nonblocking so the accept loop can poll
/// engine shutdown every ~20ms; each accepted stream is switched back to
/// blocking with a 200ms read timeout, which [`serve_connection`] sees as
/// [`LineRead::Idle`] between requests and uses as its own shutdown poll.
/// Connection threads are scoped, so the daemon returns only after every
/// connection has drained its writer.
///
/// Per-connection I/O failures (a client disconnecting mid-response, a
/// broken pipe, an accept error) are logged and the daemon moves on; the
/// seed code instead propagated the first such error, killing the daemon
/// and unlinking the socket. Only failure to bind ends the loop with an
/// error.
#[cfg(unix)]
pub fn serve_unix(engine: &ServeEngine, path: &std::path::Path) -> std::io::Result<()> {
    use std::os::unix::net::UnixListener;
    use std::time::Duration;
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)?;
    listener.set_nonblocking(true)?;
    std::thread::scope(|scope| {
        loop {
            if engine.is_shutdown() {
                break;
            }
            let stream = match listener.accept() {
                Ok((s, _addr)) => s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
                Err(e) => {
                    eprintln!("serve: accept failed ({e}); continuing");
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
            };
            let setup = stream
                .set_nonblocking(false)
                .and_then(|()| stream.set_read_timeout(Some(Duration::from_millis(200))))
                .and_then(|()| stream.try_clone());
            let reader = match setup {
                Ok(s) => std::io::BufReader::new(s),
                Err(e) => {
                    eprintln!("serve: connection setup failed ({e}); continuing");
                    continue;
                }
            };
            scope.spawn(move || {
                let mut writer = stream;
                if let Err(e) = serve_connection(engine, reader, &mut writer) {
                    // Rust ignores SIGPIPE, so a client that vanished
                    // mid-response surfaces here as a plain io::Error —
                    // never daemon death.
                    eprintln!("serve: connection error ({e}); continuing");
                }
            });
        }
    });
    let _ = std::fs::remove_file(path);
    Ok(())
}
