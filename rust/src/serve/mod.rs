//! `cggm serve` — the long-lived multi-dataset serving runtime.
//!
//! The paper's point is that one machine can solve million-dimensional
//! CGGM problems; this subsystem is what lets one *process* keep doing so
//! under repeat traffic. Historically every `cggm fit` paid the full
//! dataset-read + Gram-statistics + coloring/clustering setup before the
//! first Newton step; `serve` keeps that state alive between jobs:
//!
//! - [`registry`] — named, long-lived warm [`SolverContext`]s (raw data,
//!   `S_yy`/`S_xx`/`S_xy`, clustering partitions, CD colorings, cached
//!   warm-start models), LRU-evicted against one shared
//!   [`MemBudget`](crate::util::membudget::MemBudget);
//! - [`engine`] — a bounded worker pool draining a FIFO queue of
//!   admission-controlled `fit` / `path` / `cv` / `load` / `evict` /
//!   `stat` jobs, with submit-time peak-bytes estimates from the memwall
//!   estimators and a persistent
//!   [`TeamPool`](crate::util::threadpool::TeamPool) shared across jobs;
//! - [`protocol`] — the JSONL request/response schema (job keys are config
//!   keys);
//! - [`batch`] — `cggm batch FILE`: a manifest of jobs through the same
//!   engine, so offline sweeps and the daemon share one code path.
//!
//! Transport is stdio by default ([`serve_connection`] on
//! stdin/stdout) or a unix socket (`--socket PATH`, [`serve_unix`]) —
//! connections come and go, the engine and its warm registry persist.
//!
//! [`SolverContext`]: crate::solvers::SolverContext

pub mod batch;
pub mod engine;
pub mod protocol;
pub mod registry;

pub use batch::{run_batch, BatchOutcome};
pub use engine::ServeEngine;
pub use protocol::{ErrKind, Op, Request, Response};
pub use registry::{Registry, WarmContext};

use std::io::{BufRead, Write};
use std::sync::mpsc;

/// Serve one JSONL connection: requests read line-by-line from `reader`
/// (submitted in order), responses written as they complete by a writer
/// thread. Returns when the client disconnects (EOF) or sends
/// `{"op":"shutdown"}`, after draining every in-flight job — the engine
/// itself stays alive (socket mode serves the next connection with the
/// registry still warm).
pub fn serve_connection<R: BufRead, W: Write + Send>(
    engine: &ServeEngine,
    reader: R,
    writer: &mut W,
) -> std::io::Result<()> {
    let (tx, rx) = mpsc::channel::<Response>();
    std::thread::scope(|scope| {
        let writer_thread = scope.spawn(move || -> std::io::Result<()> {
            for resp in rx {
                writeln!(writer, "{}", resp.to_json().to_string())?;
                writer.flush()?;
            }
            Ok(())
        });
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            match Request::parse_line(&line) {
                Ok(req) => {
                    let is_shutdown = matches!(req.op, Op::Shutdown);
                    engine.submit(req, &tx);
                    if is_shutdown {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Response::err(0, "parse", ErrKind::Parse, e));
                }
            }
        }
        // Every queued job holds a reply sender clone; once the queue
        // drains and this original drops, the writer's channel closes.
        drop(tx);
        engine.drain();
        writer_thread.join().expect("writer thread panicked")
    })
}

/// Serve JSONL connections on a unix socket, one client at a time, until a
/// client sends `{"op":"shutdown"}`. The warm registry persists across
/// connections — that is the whole point.
#[cfg(unix)]
pub fn serve_unix(engine: &ServeEngine, path: &std::path::Path) -> std::io::Result<()> {
    use std::os::unix::net::UnixListener;
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)?;
    for conn in listener.incoming() {
        let stream = conn?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        serve_connection(engine, reader, &mut writer)?;
        if engine.is_shutdown() {
            break;
        }
    }
    let _ = std::fs::remove_file(path);
    Ok(())
}
