//! Shared per-problem solver state: cached covariance statistics, the
//! workspace arena, the GEMM engine handle, and the parallelism degree.
//!
//! The paper's speed argument leans on reusing the expensive quadratics —
//! `S_yy = YᵀY/n` (q×q), `S_xx = XᵀX/n` (p×p), `S_xy = XᵀY/n` (p×q) are
//! functions of the *data only*, yet historically every solver invocation
//! recomputed them from scratch. [`SolverContext`] owns them once, computed
//! lazily on first use and shared by every subsequent solve on the same
//! context — which is what makes warm-started λ-path sweeps
//! ([`crate::coordinator::fit_path`]) pay the O(nq² + np² + npq) Gram cost
//! exactly once for the whole path.
//!
//! The context also owns the [`Workspace`] arena, so cached statistics and
//! hot-loop scratch draw on one [`MemBudget`]: `peak()` measures the
//! dominant dense working set — statistics, Σ/Ψ/gradient buffers, column
//! caches, GEMM panels, *and* every Λ Cholesky factor (dense `L`, sparse
//! fill, one per line-search trial; see
//! [`crate::cggm::factor::LambdaFactor::factor_tracked`]) — for all four
//! solvers. `peak()` is the `memwall` experiment's measured column, now
//! covering every byte the solvers touch.
//!
//! The context additionally persists the block solver's graph-clustering
//! partitions ([`Self::cluster_caches`]): along a λ path, supports change
//! slowly, so `alt_newton_bcd` reuses the partition across outer iterations
//! and adjacent path points instead of re-deriving its column clusterings at
//! every point, re-clustering only when active-set churn crosses
//! `SolveOptions::recluster_churn`.
//!
//! Laziness matters for the memory story: the block solver (Algorithm 2)
//! never touches the dense statistics, so creating a context for it
//! materializes nothing; `prox_grad` pulls only `S_yy`/`S_xy` (it is
//! n-factored and never forms the p×p Gram).

use std::cell::{Cell, OnceCell, RefCell, RefMut};

use super::workspace::Workspace;
use super::{SolveError, SolveOptions, StatMode};
use crate::cggm::factor::CholKind;
use crate::cggm::tiles::{correct_tile_mat, TileKey, TileStats, TileStore};
use crate::cggm::{CggmModel, Dataset, Objective, WindowDelta};
use crate::gemm::GemmEngine;
use crate::graph::cluster::PersistentPartition;
use crate::graph::coloring::ColoringCache;
use crate::linalg::dense::Mat;
use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};
use crate::util::threadpool::Parallelism;

/// A cached statistic with its budget registration (lives as long as the
/// context, so `MemBudget::live()` reflects it).
struct CachedMat {
    mat: Mat,
    _track: Tracked,
}

/// The block solver's persisted clustering partitions: one for the Λ column
/// blocks, one for the Θ output-column blocks. Owned by the context so they
/// survive across solves (and hence across adjacent λ-path points).
#[derive(Default)]
pub struct ClusterCaches {
    pub lambda: PersistentPartition,
    pub theta: PersistentPartition,
}

/// The colored CD sweeps' conflict-graph colorings (one per parameter),
/// persisted next to the clustering partitions for the same reason: the
/// active set changes slowly across inner sweeps, outer iterations, and
/// adjacent λ-path points, so the coloring is reused or incrementally
/// extended instead of rebuilt (churn-gated by
/// [`crate::solvers::SolveOptions::recluster_churn`]; buffers registered
/// against the context's [`MemBudget`]).
#[derive(Default)]
pub struct ColoringCaches {
    pub lambda: ColoringCache,
    pub theta: ColoringCache,
}

/// The carryable statistics of a retired context: when a sliding-window
/// re-fit replaces the [`Dataset`] (and hence the context borrowing it), the
/// expensive caches — dense Gram matrices, resident tiles, clustering
/// partitions, CD colorings — survive the swap through this bag instead of
/// being recomputed. Budget registrations are *not* carried (each `Tracked`
/// is released on teardown); [`SolverContext::with_carry`] re-registers
/// against the new context's budget and silently drops whatever no longer
/// fits — a carry is a cache, never a correctness requirement. The carried
/// matrices describe the *old* window; apply
/// [`SolverContext::update_stats`] with the window delta before solving.
pub struct StatCarry {
    syy: Option<Mat>,
    sxx: Option<Mat>,
    sxy: Option<Mat>,
    sxx_diag: Option<Vec<f64>>,
    tiles: Vec<(TileKey, Mat)>,
    tile_stats: TileStats,
    /// Tile edge the carried tiles were built with (0 when none) — adoption
    /// refuses a geometry mismatch.
    tile: usize,
    clusters: ClusterCaches,
    colorings: ColoringCaches,
    stat_computes: usize,
    stat_updates: usize,
    downdates: usize,
}

impl StatCarry {
    /// Dims of the carried dense stats, for sanity checks: (p, q) from
    /// whichever matrices are present (0 when unknown).
    fn dims(&self) -> (usize, usize) {
        let q = self
            .syy
            .as_ref()
            .map(|m| m.rows())
            .or(self.sxy.as_ref().map(|m| m.cols()))
            .unwrap_or(0);
        let p = self
            .sxx
            .as_ref()
            .map(|m| m.rows())
            .or(self.sxy.as_ref().map(|m| m.rows()))
            .unwrap_or(0);
        (p, q)
    }
}

/// Shared state for one dataset: construct once, run many solves.
pub struct SolverContext<'a> {
    data: &'a Dataset,
    engine: &'a dyn GemmEngine,
    par: Parallelism,
    ws: Workspace,
    syy: OnceCell<CachedMat>,
    sxx: OnceCell<CachedMat>,
    sxy: OnceCell<CachedMat>,
    sxx_diag: OnceCell<Vec<f64>>,
    stat_computes: Cell<usize>,
    stat_mode: StatMode,
    tiles: OnceCell<TileStore<'a>>,
    /// Tiles adopted from a [`StatCarry`], parked until the lazily built
    /// [`TileStore`] exists to receive them (consumed inside [`Self::tiles`]).
    tile_carry: RefCell<Option<(Vec<(TileKey, Mat)>, TileStats)>>,
    /// Cached statistics corrected in place by [`Self::update_stats`]
    /// (dense matrices, the S_xx diagonal, and resident tiles) over the
    /// context's lifetime — surfaced on `SolveTrace::stat_updates`.
    stat_updates: Cell<usize>,
    /// Window updates that removed samples since the last full rebuild —
    /// the drift-accumulation guard's counter (see [`Self::update_stats`]).
    downdates: Cell<usize>,
    /// Force a from-scratch statistics rebuild after this many downdates
    /// (0 = never); bounds floating-point drift from repeated subtractive
    /// rank-k corrections.
    stat_rebuild_every: usize,
    clusters: RefCell<ClusterCaches>,
    colorings: RefCell<ColoringCaches>,
}

impl<'a> SolverContext<'a> {
    pub fn new(
        data: &'a Dataset,
        opts: &SolveOptions,
        engine: &'a dyn GemmEngine,
    ) -> SolverContext<'a> {
        // Disk-backed datasets register their resident panels against the
        // same budget as the workspace and cached statistics, so `peak()`
        // covers the panel cache too (a no-op rebind keeps the cache warm).
        data.bind_panel_budget(&opts.budget);
        SolverContext {
            data,
            engine,
            par: opts.parallelism(),
            ws: Workspace::new(opts.budget.clone()),
            syy: OnceCell::new(),
            sxx: OnceCell::new(),
            sxy: OnceCell::new(),
            sxx_diag: OnceCell::new(),
            stat_computes: Cell::new(0),
            stat_mode: opts.stat_mode,
            tiles: OnceCell::new(),
            tile_carry: RefCell::new(None),
            stat_updates: Cell::new(0),
            downdates: Cell::new(0),
            stat_rebuild_every: opts.stat_rebuild_every,
            clusters: RefCell::new(ClusterCaches::default()),
            colorings: RefCell::new(ColoringCaches::default()),
        }
    }

    /// Build a context seeded from a retired context's [`StatCarry`]: dense
    /// statistics are re-registered against this context's budget (dropped
    /// silently when they no longer fit — the next read recomputes), carried
    /// tiles wait for the lazy [`TileStore`] (and are discarded on a
    /// stat-mode or tile-size mismatch), and the clustering/coloring caches
    /// plus lifetime counters transfer as-is. The carry must come from the
    /// same (p, q) problem; the carried values describe the *old* window, so
    /// call [`Self::update_stats`] with the window delta before solving.
    pub fn with_carry(
        data: &'a Dataset,
        opts: &SolveOptions,
        engine: &'a dyn GemmEngine,
        carry: StatCarry,
    ) -> SolverContext<'a> {
        let (cp, cq) = carry.dims();
        assert!(
            (cp == 0 || cp == data.p()) && (cq == 0 || cq == data.q()),
            "stat carry from a different problem shape: ({cp}, {cq}) vs ({}, {})",
            data.p(),
            data.q()
        );
        let ctx = SolverContext::new(data, opts, engine);
        fn adopt(budget: &MemBudget, cell: &OnceCell<CachedMat>, mat: Option<Mat>) {
            if let Some(mat) = mat {
                if let Ok(track) = budget.track(mat.bytes()) {
                    let _ = cell.set(CachedMat { mat, _track: track });
                }
            }
        }
        adopt(ctx.ws.budget(), &ctx.syy, carry.syy);
        adopt(ctx.ws.budget(), &ctx.sxx, carry.sxx);
        adopt(ctx.ws.budget(), &ctx.sxy, carry.sxy);
        if let Some(diag) = carry.sxx_diag {
            if diag.len() == data.p() {
                let _ = ctx.sxx_diag.set(diag);
            }
        }
        if !carry.tiles.is_empty() && ctx.stat_mode == StatMode::Tiled(carry.tile) {
            *ctx.tile_carry.borrow_mut() = Some((carry.tiles, carry.tile_stats));
        }
        ctx.stat_computes.set(carry.stat_computes);
        ctx.stat_updates.set(carry.stat_updates);
        ctx.downdates.set(carry.downdates);
        *ctx.clusters.borrow_mut() = carry.clusters;
        *ctx.colorings.borrow_mut() = carry.colorings;
        ctx
    }

    /// Tear the context down into the parts worth keeping across a dataset
    /// swap (see [`StatCarry`]). Every `Tracked` registration is released
    /// here; the adopting context re-registers.
    pub fn into_carry(self) -> StatCarry {
        let tile = match self.stat_mode {
            StatMode::Tiled(t) => t,
            StatMode::Dense => 0,
        };
        let (tiles, tile_stats) = match self.tiles.into_inner() {
            Some(store) => store.into_parts(),
            None => self.tile_carry.into_inner().unwrap_or_default(),
        };
        StatCarry {
            syy: self.syy.into_inner().map(|c| c.mat),
            sxx: self.sxx.into_inner().map(|c| c.mat),
            sxy: self.sxy.into_inner().map(|c| c.mat),
            sxx_diag: self.sxx_diag.into_inner(),
            tiles,
            tile_stats,
            tile,
            clusters: self.clusters.into_inner(),
            colorings: self.colorings.into_inner(),
            stat_computes: self.stat_computes.get(),
            stat_updates: self.stat_updates.get(),
            downdates: self.downdates.get(),
        }
    }

    /// Apply a sliding-window transition to every *materialized* statistic:
    /// the symmetric rank-k correction
    /// `S ← (old_n·S + A·Aᵀ − R·Rᵀ)/new_n` runs in O(k·(p+q)²) on whatever
    /// is cached — dense blocks and the S_xx diagonal in place, resident
    /// tiles (built or still parked in the carry) through
    /// [`TileStore::apply_update`] — instead of the O(n·(p+q)²) rebuild.
    /// Statistics not yet materialized stay lazy (their next read computes
    /// from the already-updated dataset). `self.data` must already describe
    /// the post-transition window.
    ///
    /// Drift guard: every update that *removes* samples is a subtractive
    /// correction whose floating-point error compounds (catastrophic
    /// cancellation when the evicted samples dominated a statistic — see
    /// docs/PERF.md). After `stat_rebuild_every` such downdates all cached
    /// statistics are invalidated, forcing an exact rebuild on next read,
    /// and the counter resets.
    ///
    /// The correction's panel working set (the delta blocks it reads) is
    /// registered against the budget for the duration of the call, so
    /// `MemBudget::peak()` keeps measuring the true working set.
    pub fn update_stats(&mut self, delta: &WindowDelta) -> Result<(), BudgetExceeded> {
        if delta.is_empty() {
            return Ok(());
        }
        let new_n = delta.new_n();
        assert!(new_n > 0, "window update emptied the dataset");
        assert_eq!(new_n, self.data.n(), "update_stats out of sync with data");
        if delta.removed_k() > 0 {
            let d = self.downdates.get() + 1;
            self.downdates.set(d);
            if self.stat_rebuild_every > 0 && d >= self.stat_rebuild_every {
                self.invalidate_stats();
                return Ok(());
            }
        }
        let block_bytes =
            |b: &Option<crate::cggm::SampleBlock>| b.as_ref().map_or(0, |b| b.xt.bytes() + b.yt.bytes());
        let _scratch = self
            .ws
            .budget()
            .track(block_bytes(&delta.added) + block_bytes(&delta.removed))?;
        let ratio = delta.old_n as f64 / new_n as f64;
        let inv = 1.0 / new_n as f64;
        let engine = self.engine;
        let mut corrected = 0usize;
        let mut dense = |cell: &mut OnceCell<CachedMat>,
                         side: fn(&crate::cggm::SampleBlock) -> (&Mat, &Mat),
                         sym: bool| {
            if let Some(c) = cell.get_mut() {
                c.mat.scale(ratio);
                if let Some(a) = &delta.added {
                    let (pa, pb) = side(a);
                    engine.gemm_nt(inv, pa, pb, 1.0, &mut c.mat);
                }
                if let Some(r) = &delta.removed {
                    let (pa, pb) = side(r);
                    engine.gemm_nt(-inv, pa, pb, 1.0, &mut c.mat);
                }
                if sym {
                    c.mat.symmetrize();
                }
                corrected += 1;
            }
        };
        dense(&mut self.syy, |b| (&b.yt, &b.yt), true);
        dense(&mut self.sxx, |b| (&b.xt, &b.xt), true);
        dense(&mut self.sxy, |b| (&b.xt, &b.yt), false);
        if let Some(diag) = self.sxx_diag.get_mut() {
            for (i, d) in diag.iter_mut().enumerate() {
                *d *= ratio;
                if let Some(a) = &delta.added {
                    for k in 0..a.k() {
                        *d += inv * a.xt[(i, k)] * a.xt[(i, k)];
                    }
                }
                if let Some(r) = &delta.removed {
                    for k in 0..r.k() {
                        *d -= inv * r.xt[(i, k)] * r.xt[(i, k)];
                    }
                }
            }
            corrected += 1;
        }
        if let Some(store) = self.tiles.get() {
            corrected += store.apply_update(delta);
        } else if let Some((tiles, stats)) = self.tile_carry.borrow_mut().as_mut() {
            if let StatMode::Tiled(t) = self.stat_mode {
                for (key, mat) in tiles.iter_mut() {
                    correct_tile_mat(mat, *key, t, engine, delta);
                }
                stats.updates += tiles.len();
                corrected += tiles.len();
            }
        }
        self.stat_updates.set(self.stat_updates.get() + corrected);
        Ok(())
    }

    /// Drop every cached statistic (dense, diagonal, tiles, parked carry) so
    /// the next read rebuilds exactly from the current dataset, and reset
    /// the downdate counter. The rebuild is visible through
    /// [`Self::stat_computes`] growing again.
    pub fn invalidate_stats(&mut self) {
        self.syy = OnceCell::new();
        self.sxx = OnceCell::new();
        self.sxy = OnceCell::new();
        self.sxx_diag = OnceCell::new();
        self.tiles = OnceCell::new();
        *self.tile_carry.borrow_mut() = None;
        self.downdates.set(0);
    }

    /// The block solver's persisted clustering partitions (exclusive borrow
    /// for the duration of one clustering decision — hold it only inside the
    /// partition phase).
    pub fn cluster_caches(&self) -> RefMut<'_, ClusterCaches> {
        self.clusters.borrow_mut()
    }

    /// The colored CD sweeps' persisted conflict colorings (exclusive
    /// borrow for the duration of one CD phase).
    pub fn coloring_caches(&self) -> RefMut<'_, ColoringCaches> {
        self.colorings.borrow_mut()
    }

    pub fn data(&self) -> &'a Dataset {
        self.data
    }

    pub fn engine(&self) -> &'a dyn GemmEngine {
        self.engine
    }

    pub fn par(&self) -> &Parallelism {
        &self.par
    }

    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    pub fn budget(&self) -> &MemBudget {
        self.ws.budget()
    }

    fn cached<'s>(
        &'s self,
        cell: &'s OnceCell<CachedMat>,
        bytes: usize,
        compute: impl FnOnce() -> Mat,
    ) -> Result<&'s Mat, BudgetExceeded> {
        if cell.get().is_none() {
            // Register before computing so an over-budget statistic fails
            // cleanly instead of allocating first.
            let track = self.ws.budget().track(bytes)?;
            self.stat_computes.set(self.stat_computes.get() + 1);
            let _ = cell.set(CachedMat {
                mat: compute(),
                _track: track,
            });
        }
        Ok(&cell.get().expect("cell just populated").mat)
    }

    /// Dense S_yy (q×q), computed once per context.
    pub fn syy(&self) -> Result<&Mat, BudgetExceeded> {
        let q = self.data.q();
        self.cached(&self.syy, 8 * q * q, || self.data.syy_dense(self.engine))
    }

    /// Dense S_xx (p×p), computed once per context. The block solver never
    /// calls this — its absence is Algorithm 2's memory story.
    pub fn sxx(&self) -> Result<&Mat, BudgetExceeded> {
        let p = self.data.p();
        self.cached(&self.sxx, 8 * p * p, || self.data.sxx_dense(self.engine))
    }

    /// Dense S_xy (p×q), computed once per context.
    pub fn sxy(&self) -> Result<&Mat, BudgetExceeded> {
        let (p, q) = (self.data.p(), self.data.q());
        self.cached(&self.sxy, 8 * p * q, || self.data.sxy_dense(self.engine))
    }

    /// diag(S_xx), computed directly in O(pn) — does not force the dense p×p.
    pub fn sxx_diag(&self) -> &[f64] {
        self.sxx_diag
            .get_or_init(|| (0..self.data.p()).map(|i| self.data.sxx(i, i)).collect())
    }

    /// How many dense statistics have been materialized (tests assert a
    /// λ-path computes each exactly once).
    pub fn stat_computes(&self) -> usize {
        self.stat_computes.get()
    }

    /// Cached statistics corrected in place by [`Self::update_stats`] over
    /// the context's lifetime (dense matrices + S_xx diagonal + resident
    /// tiles). Copied onto `SolveTrace::stat_updates` by `solve_in_context`.
    pub fn stat_updates(&self) -> usize {
        self.stat_updates.get()
    }

    /// Sample-removing window updates since the last full statistics rebuild
    /// — the drift guard's counter (resets when it trips or on
    /// [`Self::invalidate_stats`]).
    pub fn downdates(&self) -> usize {
        self.downdates.get()
    }

    /// The context's statistics materialization mode.
    pub fn stat_mode(&self) -> StatMode {
        self.stat_mode
    }

    /// The demand-driven tile cache, when the context runs in
    /// [`StatMode::Tiled`] — created lazily on first use so a dense-mode (or
    /// never-tiled) context materializes nothing. The store shares the
    /// context's budget: resident tiles and dense caches draw on one limit.
    pub fn tiles(&self) -> Option<&TileStore<'a>> {
        match self.stat_mode {
            StatMode::Dense => None,
            StatMode::Tiled(tile) => Some(self.tiles.get_or_init(|| {
                let store =
                    TileStore::new(self.data, self.engine, self.ws.budget().clone(), tile);
                // Tiles parked by a carry adoption (already corrected to the
                // current window) seed the fresh store.
                if let Some((tiles, stats)) = self.tile_carry.borrow_mut().take() {
                    store.adopt(tiles, stats);
                }
                store
            })),
        }
    }

    /// Snapshot of the tile cache's counters (`None` until a tiled solve has
    /// touched it) — the solvers copy this onto their `SolveTrace`.
    pub fn tile_stats(&self) -> Option<TileStats> {
        self.tiles.get().map(TileStore::stats)
    }

    /// Bytes currently pinned by materialized dense statistics — what a
    /// long-lived registry entry "costs" while it stays warm (the serve
    /// registry's accounting and `stat` responses read this).
    pub fn cached_stat_bytes(&self) -> usize {
        let (p, q) = (self.data.p(), self.data.q());
        let mut bytes = 0usize;
        if self.syy.get().is_some() {
            bytes += 8 * q * q;
        }
        if self.sxx.get().is_some() {
            bytes += 8 * p * p;
        }
        if self.sxy.get().is_some() {
            bytes += 8 * p * q;
        }
        if let Some(tiles) = self.tiles.get() {
            bytes += tiles.resident_bytes();
        }
        bytes
    }

    /// Dense gradients of the *smooth* objective at `model`:
    /// `(∇_Λ g, ∇_Θ g)` per Eq. 3, from the context's cached statistics
    /// (`S_yy`, `S_xy`; `S_xx` is never formed — ∇_Θ is n-factored). All
    /// scratch (Σ, R̃ᵀ, Σ·R̃ᵀ, Ψ) comes budget-tracked from the workspace
    /// arena; only the two returned matrices are plain owned allocations
    /// (q² + pq bytes of driver state — the same footprint as one cached
    /// statistic — which must outlive the checkout scope). One factorization
    /// + O(q²n + npq) of GEMM — an outer iteration's worth of work. The
    /// λ-path driver calls this once per path point to build the next
    /// strong-rule screen set and run the KKT post-check
    /// (`coordinator::solve_screened`).
    pub fn smooth_gradients(
        &self,
        model: &CggmModel,
        chol: CholKind,
    ) -> Result<(Mat, Mat), SolveError> {
        let data = self.data;
        let (p, q, n) = (data.p(), data.q(), data.n());
        let obj = Objective::new(data, 0.0, 0.0)
            .with_chol(chol)
            .with_budget(self.ws.budget().clone());
        let factor = obj.factor_lambda(&model.lambda, self.engine)?;
        let mut gl = self.syy()?.clone();
        let mut gt = Mat::zeros(p, q);
        {
            let mut sigma = self.ws.mat(q, q)?;
            super::alt_newton_cd::sigma_dense_into(
                &factor,
                self.engine,
                &self.par,
                &self.ws,
                &mut sigma,
            )?;
            let mut rt = self.ws.mat(q, n)?;
            data.xtheta_t_into(&model.theta, &mut rt);
            let mut sr = self.ws.mat(q, n)?;
            let mut psi = self.ws.mat(q, q)?;
            obj.psi_into(&sigma, &rt, self.engine, &mut sr, &mut psi);
            gl.add_scaled(-1.0, &sigma);
            gl.add_scaled(-1.0, &psi);
            obj.grad_theta_from_sr(self.sxy()?, &sr, self.engine, &mut gt);
        }
        Ok((gl, gt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;

    fn small_data(rng: &mut Rng, n: usize, p: usize, q: usize) -> Dataset {
        Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    #[test]
    fn statistics_computed_once_and_cached() {
        let mut rng = Rng::new(3);
        let data = small_data(&mut rng, 12, 5, 7);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions::default();
        let ctx = SolverContext::new(&data, &opts, &eng);
        let a = ctx.syy().unwrap() as *const Mat;
        let b = ctx.syy().unwrap() as *const Mat;
        assert_eq!(a, b, "second call must return the cached matrix");
        let _ = ctx.sxx().unwrap();
        let _ = ctx.sxy().unwrap();
        let _ = ctx.sxy().unwrap();
        assert_eq!(ctx.stat_computes(), 3);
        // Values agree with the direct computation.
        let want = data.syy_dense(&eng);
        assert!(ctx.syy().unwrap().max_abs_diff(&want) < 1e-14);
        for (i, d) in ctx.sxx_diag().iter().enumerate() {
            assert!((d - data.sxx(i, i)).abs() < 1e-14);
        }
    }

    #[test]
    fn cached_statistics_count_against_the_budget() {
        let mut rng = Rng::new(4);
        let data = small_data(&mut rng, 10, 4, 6);
        let eng = NativeGemm::new(1);
        let budget = MemBudget::unlimited();
        let opts = SolveOptions {
            budget: budget.clone(),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert_eq!(budget.live(), 0);
        let _ = ctx.syy().unwrap();
        assert_eq!(budget.live(), 8 * 6 * 6);
        let _ = ctx.sxy().unwrap();
        assert_eq!(budget.live(), 8 * 6 * 6 + 8 * 4 * 6);
    }

    #[test]
    fn smooth_gradients_match_objective_dense_path() {
        let mut rng = Rng::new(6);
        let data = small_data(&mut rng, 14, 5, 6);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions::default();
        let ctx = SolverContext::new(&data, &opts, &eng);
        let mut model = CggmModel::init(5, 6);
        for i in 0..6 {
            model.lambda.set(i, i, 2.5 + 0.1 * i as f64);
        }
        model.lambda.set_sym(0, 3, 0.3);
        model.theta.set(2, 1, -0.4);
        model.theta.set(4, 5, 0.7);
        let (gl, gt) = ctx.smooth_gradients(&model, CholKind::Auto).unwrap();
        // Reference: the Objective's allocating dense path.
        let obj = Objective::new(&data, 0.0, 0.0);
        let (_, _, factor, rt) = obj.eval(&model, &eng).unwrap();
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        let want_gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
        let want_gt = obj.grad_theta_dense(&sigma, &rt, &eng);
        assert!(gl.max_abs_diff(&want_gl) < 1e-10);
        assert!(gt.max_abs_diff(&want_gt) < 1e-10);
        // Uses only the cached S_yy and S_xy — S_xx is never materialized.
        assert_eq!(ctx.stat_computes(), 2);
    }

    #[test]
    fn tiled_context_reads_through_tile_cache() {
        let mut rng = Rng::new(7);
        let data = small_data(&mut rng, 10, 6, 4);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            stat_mode: StatMode::Tiled(3),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert!(ctx.tile_stats().is_none(), "lazy until first touch");
        assert_eq!(ctx.cached_stat_bytes(), 0);
        let ts = ctx.tiles().expect("tiled mode exposes the store");
        assert!((ts.sxx_entry(1, 5) - data.sxx(1, 5)).abs() < 1e-12);
        assert!((ts.sxy_entry(4, 2) - data.sxy(4, 2)).abs() < 1e-12);
        let st = ctx.tile_stats().unwrap();
        assert_eq!(st.computes, 2);
        // Resident tiles show up in the context's pinned-byte accounting.
        assert!(ctx.cached_stat_bytes() > 0);
        // A dense-mode context never creates a store.
        let dense = SolverContext::new(&data, &SolveOptions::default(), &eng);
        assert!(dense.tiles().is_none());
    }

    #[test]
    fn update_stats_matches_recompute_over_random_rounds() {
        use crate::cggm::dataset::SampleBlock;
        use crate::util::testing::property;
        // The tentpole numerical-safety property at the unit level: after
        // random append/evict rounds the incrementally maintained dense
        // statistics match a from-scratch recompute at 1e-10.
        property(10, |rng| {
            let (n, p, q) = (5 + rng.below(8), 1 + rng.below(6), 1 + rng.below(5));
            let eng = NativeGemm::new(1);
            let opts = SolveOptions::default();
            let mut data = Dataset::new(
                Mat::from_fn(p, n, |_, _| rng.normal()),
                Mat::from_fn(q, n, |_, _| rng.normal()),
            );
            let mut carry: Option<StatCarry> = None;
            for _round in 0..6 {
                let snapshot = data.clone();
                let ctx = match carry.take() {
                    Some(c) => SolverContext::with_carry(&snapshot, &opts, &eng, c),
                    None => SolverContext::new(&snapshot, &opts, &eng),
                };
                let _ = ctx.syy().map_err(|e| e.to_string())?;
                let _ = ctx.sxx().map_err(|e| e.to_string())?;
                let _ = ctx.sxy().map_err(|e| e.to_string())?;
                let _ = ctx.sxx_diag();
                // Slide: append ka, evict kr ≤ ka (window never shrinks
                // below its starting occupancy, so it never empties).
                let ka = 1 + rng.below(3);
                let kr = rng.below(ka + 1);
                let added = SampleBlock::new(
                    Mat::from_fn(p, ka, |_, _| rng.normal()),
                    Mat::from_fn(q, ka, |_, _| rng.normal()),
                );
                let mut delta = crate::cggm::WindowDelta::new(data.n());
                data.append_block(&added).unwrap();
                delta.record_append(added);
                delta.record_evict(data.evict_oldest(kr).unwrap());
                // The context still borrows `snapshot`; re-home it on the
                // slid window through the carry before updating.
                let c = ctx.into_carry();
                let mut ctx = SolverContext::with_carry(&data, &opts, &eng, c);
                let before = ctx.stat_computes();
                ctx.update_stats(&delta).map_err(|e| e.to_string())?;
                if ctx.stat_computes() != before {
                    return Err("update must not recompute".into());
                }
                let syy = data.syy_dense(&eng);
                let sxx = data.sxx_dense(&eng);
                let sxy = data.sxy_dense(&eng);
                let e1 = ctx.syy().map_err(|e| e.to_string())?.max_abs_diff(&syy);
                let e2 = ctx.sxx().map_err(|e| e.to_string())?.max_abs_diff(&sxx);
                let e3 = ctx.sxy().map_err(|e| e.to_string())?.max_abs_diff(&sxy);
                if e1 > 1e-10 || e2 > 1e-10 || e3 > 1e-10 {
                    return Err(format!("drift: syy {e1:.2e} sxx {e2:.2e} sxy {e3:.2e}"));
                }
                for (i, d) in ctx.sxx_diag().iter().enumerate() {
                    if (d - sxx[(i, i)]).abs() > 1e-10 {
                        return Err(format!("diag drift at {i}"));
                    }
                }
                carry = Some(ctx.into_carry());
            }
            Ok(())
        });
    }

    #[test]
    fn rebuild_guard_trips_after_configured_downdates() {
        use crate::cggm::dataset::SampleBlock;
        let mut rng = Rng::new(9);
        let mut data = small_data(&mut rng, 10, 3, 4);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            stat_rebuild_every: 3,
            ..Default::default()
        };
        let snapshot = data.clone();
        let mut ctx = SolverContext::new(&snapshot, &opts, &eng);
        let _ = ctx.syy().unwrap();
        assert_eq!(ctx.stat_computes(), 1);
        for round in 1..=3usize {
            // Consume the context *before* mutating `data` (rounds ≥ 2
            // borrow it), exactly as the serve refit path does.
            let c = ctx.into_carry();
            let added = SampleBlock::new(
                Mat::from_fn(3, 1, |_, _| rng.normal()),
                Mat::from_fn(4, 1, |_, _| rng.normal()),
            );
            let mut delta = crate::cggm::WindowDelta::new(data.n());
            data.append_block(&added).unwrap();
            delta.record_append(added);
            delta.record_evict(data.evict_oldest(1).unwrap());
            ctx = SolverContext::with_carry(&data, &opts, &eng, c);
            ctx.update_stats(&delta).unwrap();
            if round < 3 {
                assert_eq!(ctx.downdates(), round, "counter pins each downdate");
            } else {
                // Third downdate trips the guard: caches dropped, counter
                // reset, next read recomputes from scratch.
                assert_eq!(ctx.downdates(), 0);
                assert_eq!(ctx.cached_stat_bytes(), 0);
                let before = ctx.stat_computes();
                let want = data.syy_dense(&eng);
                assert!(ctx.syy().unwrap().max_abs_diff(&want) < 1e-14);
                assert_eq!(ctx.stat_computes(), before + 1, "guard forces rebuild");
            }
        }
        drop(ctx);
    }

    #[test]
    fn carry_preserves_caches_without_recompute() {
        let mut rng = Rng::new(12);
        let data = small_data(&mut rng, 9, 4, 5);
        let eng = NativeGemm::new(1);
        let budget = MemBudget::unlimited();
        let opts = SolveOptions {
            budget: budget.clone(),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        let _ = ctx.syy().unwrap();
        let _ = ctx.sxy().unwrap();
        assert_eq!(ctx.stat_computes(), 2);
        let live_before = budget.live();
        let carry = ctx.into_carry(); // releases the old registrations
        assert_eq!(budget.live(), 0);
        let ctx2 = SolverContext::with_carry(&data, &opts, &eng, carry);
        assert_eq!(budget.live(), live_before, "carry re-registers the bytes");
        let want = data.syy_dense(&eng);
        assert!(ctx2.syy().unwrap().max_abs_diff(&want) < 1e-14);
        assert_eq!(ctx2.stat_computes(), 2, "no recompute after adoption");
    }

    #[test]
    fn over_budget_statistic_is_an_error() {
        let mut rng = Rng::new(5);
        let data = small_data(&mut rng, 10, 4, 6);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            budget: MemBudget::new(64),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert!(ctx.syy().is_err(), "q²·8 = 288 bytes must not fit in 64");
        // diag never forces the dense matrix and stays available.
        assert_eq!(ctx.sxx_diag().len(), 4);
    }
}
