//! Shared per-problem solver state: cached covariance statistics, the
//! workspace arena, the GEMM engine handle, and the parallelism degree.
//!
//! The paper's speed argument leans on reusing the expensive quadratics —
//! `S_yy = YᵀY/n` (q×q), `S_xx = XᵀX/n` (p×p), `S_xy = XᵀY/n` (p×q) are
//! functions of the *data only*, yet historically every solver invocation
//! recomputed them from scratch. [`SolverContext`] owns them once, computed
//! lazily on first use and shared by every subsequent solve on the same
//! context — which is what makes warm-started λ-path sweeps
//! ([`crate::coordinator::fit_path`]) pay the O(nq² + np² + npq) Gram cost
//! exactly once for the whole path.
//!
//! The context also owns the [`Workspace`] arena, so cached statistics and
//! hot-loop scratch draw on one [`MemBudget`]: `peak()` measures the
//! dominant dense working set — statistics, Σ/Ψ/gradient buffers, column
//! caches, GEMM panels, *and* every Λ Cholesky factor (dense `L`, sparse
//! fill, one per line-search trial; see
//! [`crate::cggm::factor::LambdaFactor::factor_tracked`]) — for all four
//! solvers. `peak()` is the `memwall` experiment's measured column, now
//! covering every byte the solvers touch.
//!
//! The context additionally persists the block solver's graph-clustering
//! partitions ([`Self::cluster_caches`]): along a λ path, supports change
//! slowly, so `alt_newton_bcd` reuses the partition across outer iterations
//! and adjacent path points instead of re-deriving its column clusterings at
//! every point, re-clustering only when active-set churn crosses
//! `SolveOptions::recluster_churn`.
//!
//! Laziness matters for the memory story: the block solver (Algorithm 2)
//! never touches the dense statistics, so creating a context for it
//! materializes nothing; `prox_grad` pulls only `S_yy`/`S_xy` (it is
//! n-factored and never forms the p×p Gram).

use std::cell::{Cell, OnceCell, RefCell, RefMut};

use super::workspace::Workspace;
use super::{SolveError, SolveOptions, StatMode};
use crate::cggm::factor::CholKind;
use crate::cggm::tiles::{TileStats, TileStore};
use crate::cggm::{CggmModel, Dataset, Objective};
use crate::gemm::GemmEngine;
use crate::graph::cluster::PersistentPartition;
use crate::graph::coloring::ColoringCache;
use crate::linalg::dense::Mat;
use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};
use crate::util::threadpool::Parallelism;

/// A cached statistic with its budget registration (lives as long as the
/// context, so `MemBudget::live()` reflects it).
struct CachedMat {
    mat: Mat,
    _track: Tracked,
}

/// The block solver's persisted clustering partitions: one for the Λ column
/// blocks, one for the Θ output-column blocks. Owned by the context so they
/// survive across solves (and hence across adjacent λ-path points).
#[derive(Default)]
pub struct ClusterCaches {
    pub lambda: PersistentPartition,
    pub theta: PersistentPartition,
}

/// The colored CD sweeps' conflict-graph colorings (one per parameter),
/// persisted next to the clustering partitions for the same reason: the
/// active set changes slowly across inner sweeps, outer iterations, and
/// adjacent λ-path points, so the coloring is reused or incrementally
/// extended instead of rebuilt (churn-gated by
/// [`crate::solvers::SolveOptions::recluster_churn`]; buffers registered
/// against the context's [`MemBudget`]).
#[derive(Default)]
pub struct ColoringCaches {
    pub lambda: ColoringCache,
    pub theta: ColoringCache,
}

/// Shared state for one dataset: construct once, run many solves.
pub struct SolverContext<'a> {
    data: &'a Dataset,
    engine: &'a dyn GemmEngine,
    par: Parallelism,
    ws: Workspace,
    syy: OnceCell<CachedMat>,
    sxx: OnceCell<CachedMat>,
    sxy: OnceCell<CachedMat>,
    sxx_diag: OnceCell<Vec<f64>>,
    stat_computes: Cell<usize>,
    stat_mode: StatMode,
    tiles: OnceCell<TileStore<'a>>,
    clusters: RefCell<ClusterCaches>,
    colorings: RefCell<ColoringCaches>,
}

impl<'a> SolverContext<'a> {
    pub fn new(
        data: &'a Dataset,
        opts: &SolveOptions,
        engine: &'a dyn GemmEngine,
    ) -> SolverContext<'a> {
        SolverContext {
            data,
            engine,
            par: opts.parallelism(),
            ws: Workspace::new(opts.budget.clone()),
            syy: OnceCell::new(),
            sxx: OnceCell::new(),
            sxy: OnceCell::new(),
            sxx_diag: OnceCell::new(),
            stat_computes: Cell::new(0),
            stat_mode: opts.stat_mode,
            tiles: OnceCell::new(),
            clusters: RefCell::new(ClusterCaches::default()),
            colorings: RefCell::new(ColoringCaches::default()),
        }
    }

    /// The block solver's persisted clustering partitions (exclusive borrow
    /// for the duration of one clustering decision — hold it only inside the
    /// partition phase).
    pub fn cluster_caches(&self) -> RefMut<'_, ClusterCaches> {
        self.clusters.borrow_mut()
    }

    /// The colored CD sweeps' persisted conflict colorings (exclusive
    /// borrow for the duration of one CD phase).
    pub fn coloring_caches(&self) -> RefMut<'_, ColoringCaches> {
        self.colorings.borrow_mut()
    }

    pub fn data(&self) -> &'a Dataset {
        self.data
    }

    pub fn engine(&self) -> &'a dyn GemmEngine {
        self.engine
    }

    pub fn par(&self) -> &Parallelism {
        &self.par
    }

    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    pub fn budget(&self) -> &MemBudget {
        self.ws.budget()
    }

    fn cached<'s>(
        &'s self,
        cell: &'s OnceCell<CachedMat>,
        bytes: usize,
        compute: impl FnOnce() -> Mat,
    ) -> Result<&'s Mat, BudgetExceeded> {
        if cell.get().is_none() {
            // Register before computing so an over-budget statistic fails
            // cleanly instead of allocating first.
            let track = self.ws.budget().track(bytes)?;
            self.stat_computes.set(self.stat_computes.get() + 1);
            let _ = cell.set(CachedMat {
                mat: compute(),
                _track: track,
            });
        }
        Ok(&cell.get().expect("cell just populated").mat)
    }

    /// Dense S_yy (q×q), computed once per context.
    pub fn syy(&self) -> Result<&Mat, BudgetExceeded> {
        let q = self.data.q();
        self.cached(&self.syy, 8 * q * q, || self.data.syy_dense(self.engine))
    }

    /// Dense S_xx (p×p), computed once per context. The block solver never
    /// calls this — its absence is Algorithm 2's memory story.
    pub fn sxx(&self) -> Result<&Mat, BudgetExceeded> {
        let p = self.data.p();
        self.cached(&self.sxx, 8 * p * p, || self.data.sxx_dense(self.engine))
    }

    /// Dense S_xy (p×q), computed once per context.
    pub fn sxy(&self) -> Result<&Mat, BudgetExceeded> {
        let (p, q) = (self.data.p(), self.data.q());
        self.cached(&self.sxy, 8 * p * q, || self.data.sxy_dense(self.engine))
    }

    /// diag(S_xx), computed directly in O(pn) — does not force the dense p×p.
    pub fn sxx_diag(&self) -> &[f64] {
        self.sxx_diag
            .get_or_init(|| (0..self.data.p()).map(|i| self.data.sxx(i, i)).collect())
    }

    /// How many dense statistics have been materialized (tests assert a
    /// λ-path computes each exactly once).
    pub fn stat_computes(&self) -> usize {
        self.stat_computes.get()
    }

    /// The context's statistics materialization mode.
    pub fn stat_mode(&self) -> StatMode {
        self.stat_mode
    }

    /// The demand-driven tile cache, when the context runs in
    /// [`StatMode::Tiled`] — created lazily on first use so a dense-mode (or
    /// never-tiled) context materializes nothing. The store shares the
    /// context's budget: resident tiles and dense caches draw on one limit.
    pub fn tiles(&self) -> Option<&TileStore<'a>> {
        match self.stat_mode {
            StatMode::Dense => None,
            StatMode::Tiled(tile) => Some(self.tiles.get_or_init(|| {
                TileStore::new(self.data, self.engine, self.ws.budget().clone(), tile)
            })),
        }
    }

    /// Snapshot of the tile cache's counters (`None` until a tiled solve has
    /// touched it) — the solvers copy this onto their `SolveTrace`.
    pub fn tile_stats(&self) -> Option<TileStats> {
        self.tiles.get().map(TileStore::stats)
    }

    /// Bytes currently pinned by materialized dense statistics — what a
    /// long-lived registry entry "costs" while it stays warm (the serve
    /// registry's accounting and `stat` responses read this).
    pub fn cached_stat_bytes(&self) -> usize {
        let (p, q) = (self.data.p(), self.data.q());
        let mut bytes = 0usize;
        if self.syy.get().is_some() {
            bytes += 8 * q * q;
        }
        if self.sxx.get().is_some() {
            bytes += 8 * p * p;
        }
        if self.sxy.get().is_some() {
            bytes += 8 * p * q;
        }
        if let Some(tiles) = self.tiles.get() {
            bytes += tiles.resident_bytes();
        }
        bytes
    }

    /// Dense gradients of the *smooth* objective at `model`:
    /// `(∇_Λ g, ∇_Θ g)` per Eq. 3, from the context's cached statistics
    /// (`S_yy`, `S_xy`; `S_xx` is never formed — ∇_Θ is n-factored). All
    /// scratch (Σ, R̃ᵀ, Σ·R̃ᵀ, Ψ) comes budget-tracked from the workspace
    /// arena; only the two returned matrices are plain owned allocations
    /// (q² + pq bytes of driver state — the same footprint as one cached
    /// statistic — which must outlive the checkout scope). One factorization
    /// + O(q²n + npq) of GEMM — an outer iteration's worth of work. The
    /// λ-path driver calls this once per path point to build the next
    /// strong-rule screen set and run the KKT post-check
    /// (`coordinator::solve_screened`).
    pub fn smooth_gradients(
        &self,
        model: &CggmModel,
        chol: CholKind,
    ) -> Result<(Mat, Mat), SolveError> {
        let data = self.data;
        let (p, q, n) = (data.p(), data.q(), data.n());
        let obj = Objective::new(data, 0.0, 0.0)
            .with_chol(chol)
            .with_budget(self.ws.budget().clone());
        let factor = obj.factor_lambda(&model.lambda, self.engine)?;
        let mut gl = self.syy()?.clone();
        let mut gt = Mat::zeros(p, q);
        {
            let mut sigma = self.ws.mat(q, q)?;
            super::alt_newton_cd::sigma_dense_into(
                &factor,
                self.engine,
                &self.par,
                &self.ws,
                &mut sigma,
            )?;
            let mut rt = self.ws.mat(q, n)?;
            data.xtheta_t_into(&model.theta, &mut rt);
            let mut sr = self.ws.mat(q, n)?;
            let mut psi = self.ws.mat(q, q)?;
            obj.psi_into(&sigma, &rt, self.engine, &mut sr, &mut psi);
            gl.add_scaled(-1.0, &sigma);
            gl.add_scaled(-1.0, &psi);
            obj.grad_theta_from_sr(self.sxy()?, &sr, self.engine, &mut gt);
        }
        Ok((gl, gt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::util::rng::Rng;

    fn small_data(rng: &mut Rng, n: usize, p: usize, q: usize) -> Dataset {
        Dataset::new(
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    #[test]
    fn statistics_computed_once_and_cached() {
        let mut rng = Rng::new(3);
        let data = small_data(&mut rng, 12, 5, 7);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions::default();
        let ctx = SolverContext::new(&data, &opts, &eng);
        let a = ctx.syy().unwrap() as *const Mat;
        let b = ctx.syy().unwrap() as *const Mat;
        assert_eq!(a, b, "second call must return the cached matrix");
        let _ = ctx.sxx().unwrap();
        let _ = ctx.sxy().unwrap();
        let _ = ctx.sxy().unwrap();
        assert_eq!(ctx.stat_computes(), 3);
        // Values agree with the direct computation.
        let want = data.syy_dense(&eng);
        assert!(ctx.syy().unwrap().max_abs_diff(&want) < 1e-14);
        for (i, d) in ctx.sxx_diag().iter().enumerate() {
            assert!((d - data.sxx(i, i)).abs() < 1e-14);
        }
    }

    #[test]
    fn cached_statistics_count_against_the_budget() {
        let mut rng = Rng::new(4);
        let data = small_data(&mut rng, 10, 4, 6);
        let eng = NativeGemm::new(1);
        let budget = MemBudget::unlimited();
        let opts = SolveOptions {
            budget: budget.clone(),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert_eq!(budget.live(), 0);
        let _ = ctx.syy().unwrap();
        assert_eq!(budget.live(), 8 * 6 * 6);
        let _ = ctx.sxy().unwrap();
        assert_eq!(budget.live(), 8 * 6 * 6 + 8 * 4 * 6);
    }

    #[test]
    fn smooth_gradients_match_objective_dense_path() {
        let mut rng = Rng::new(6);
        let data = small_data(&mut rng, 14, 5, 6);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions::default();
        let ctx = SolverContext::new(&data, &opts, &eng);
        let mut model = CggmModel::init(5, 6);
        for i in 0..6 {
            model.lambda.set(i, i, 2.5 + 0.1 * i as f64);
        }
        model.lambda.set_sym(0, 3, 0.3);
        model.theta.set(2, 1, -0.4);
        model.theta.set(4, 5, 0.7);
        let (gl, gt) = ctx.smooth_gradients(&model, CholKind::Auto).unwrap();
        // Reference: the Objective's allocating dense path.
        let obj = Objective::new(&data, 0.0, 0.0);
        let (_, _, factor, rt) = obj.eval(&model, &eng).unwrap();
        let sigma = factor.inverse_dense(&eng);
        let psi = obj.psi_dense(&sigma, &rt, &eng);
        let want_gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
        let want_gt = obj.grad_theta_dense(&sigma, &rt, &eng);
        assert!(gl.max_abs_diff(&want_gl) < 1e-10);
        assert!(gt.max_abs_diff(&want_gt) < 1e-10);
        // Uses only the cached S_yy and S_xy — S_xx is never materialized.
        assert_eq!(ctx.stat_computes(), 2);
    }

    #[test]
    fn tiled_context_reads_through_tile_cache() {
        let mut rng = Rng::new(7);
        let data = small_data(&mut rng, 10, 6, 4);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            stat_mode: StatMode::Tiled(3),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert!(ctx.tile_stats().is_none(), "lazy until first touch");
        assert_eq!(ctx.cached_stat_bytes(), 0);
        let ts = ctx.tiles().expect("tiled mode exposes the store");
        assert!((ts.sxx_entry(1, 5) - data.sxx(1, 5)).abs() < 1e-12);
        assert!((ts.sxy_entry(4, 2) - data.sxy(4, 2)).abs() < 1e-12);
        let st = ctx.tile_stats().unwrap();
        assert_eq!(st.computes, 2);
        // Resident tiles show up in the context's pinned-byte accounting.
        assert!(ctx.cached_stat_bytes() > 0);
        // A dense-mode context never creates a store.
        let dense = SolverContext::new(&data, &SolveOptions::default(), &eng);
        assert!(dense.tiles().is_none());
    }

    #[test]
    fn over_budget_statistic_is_an_error() {
        let mut rng = Rng::new(5);
        let data = small_data(&mut rng, 10, 4, 6);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            budget: MemBudget::new(64),
            ..Default::default()
        };
        let ctx = SolverContext::new(&data, &opts, &eng);
        assert!(ctx.syy().is_err(), "q²·8 = 288 bytes must not fit in 64");
        // diag never forces the dense matrix and stays available.
        assert_eq!(ctx.sxx_diag().len(), 4);
    }
}
