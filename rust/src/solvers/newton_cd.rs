//! **Newton Coordinate Descent** — the previous state of the art
//! (Wytock & Kolter 2013; paper §2 "Optimization" + Appendix A.1), our
//! baseline system.
//!
//! One second-order model over the *joint* (Λ, Θ), minimized by coordinate
//! descent with the coupling terms:
//!
//! - precomputes `Γ = S_xxΘΣ` (the dense p×q matrix whose O(npq)
//!   construction the alternating method eliminates);
//! - Λ updates carry `-Φ_ij - Φ_ji`, `Φ = ΣΘᵀS_xxΔ_ΘΣ = Γᵀ V'`;
//! - Θ updates carry `+2Γ_ij - 2(ΓU)_ij` and cost O(p+q) each;
//! - one *joint* Armijo line search over (Λ + αD_Λ, Θ + αD_Θ).

use super::alt_newton_cd::{full_count, sigma_dense};
use super::cd_common::{
    lambda_cd_pass, theta_cd_pass_direction, trace_grad_dir, JointTerms,
};
use super::{SolveError, SolveOptions, SolveResult};
use crate::cggm::active::{lambda_active_dense, theta_active_dense};
use crate::cggm::factor::LambdaFactor;
use crate::cggm::linesearch::{joint_line_search, LineSearchOptions};
use crate::cggm::objective::SmoothParts;
use crate::cggm::{CggmModel, Dataset, Objective};
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::timer::{PhaseProfiler, Stopwatch};

pub fn solve(
    data: &Dataset,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
) -> Result<SolveResult, SolveError> {
    let (p, q) = (data.p(), data.q());
    let par = opts.parallelism();
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let obj = Objective::new(data, opts.lam_l, opts.lam_t).with_chol(opts.chol);
    let mut model = CggmModel::init(p, q);
    let mut trace = SolveTrace {
        solver: "newton_cd".into(),
        ..Default::default()
    };

    let syy = prof.time("cov:syy", || data.syy_dense(engine));
    let sxx = prof.time("cov:sxx", || data.sxx_dense(engine));
    let sxy = prof.time("cov:sxy", || data.sxy_dense(engine));
    let sxx_diag: Vec<f64> = (0..p).map(|i| sxx[(i, i)]).collect();

    let mut factor = LambdaFactor::factor(&model.lambda, obj.chol, engine)?;
    let mut rt = data.xtheta_t(&model.theta);
    let mut parts = SmoothParts {
        logdet: factor.logdet(),
        tr_syy_lambda: obj.tr_syy_sparse(&model.lambda),
        tr_sxy_theta: obj.tr_sxy_sparse(&model.theta),
        tr_quad: factor.trace_quad(&rt),
    };
    let mut f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    let mut sigma = prof.time("sigma", || sigma_dense(&factor, engine, &par));
    let ls_opts = LineSearchOptions::default();

    for it in 0..opts.max_iter {
        // ---- Γ, Ψ: the per-iteration dense precomputations (O(npq + nq²)) ----
        let psi = prof.time("psi", || obj.psi_dense(&sigma, &rt, engine));
        // Γ = S_xxΘΣ = Xᵀ(X·(ΘΣ))/n = gemm_nt(xt, Σ·rt)/n.
        let gamma = prof.time("gamma", || {
            let mut sr = Mat::zeros(q, data.n());
            engine.gemm(1.0, &sigma, &rt, 0.0, &mut sr);
            let mut g = Mat::zeros(p, q);
            engine.gemm_nt(data.inv_n(), &data.xt, &sr, 0.0, &mut g);
            g
        });
        let gamma_t = prof.time("gamma", || gamma.transposed());

        // ---- gradients & screens ----
        let gl = {
            let mut g = syy.clone();
            g.add_scaled(-1.0, &sigma);
            g.add_scaled(-1.0, &psi);
            g
        };
        let gt = {
            let mut g = sxy.clone();
            g.add_scaled(1.0, &gamma);
            g.scale(2.0);
            g
        };
        let (active_l, stats_l) = lambda_active_dense(&gl, &model.lambda, opts.lam_l);
        let (active_t, stats_t) = theta_active_dense(&gt, &model.theta, opts.lam_t);
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = model.lambda.l1_norm() + model.theta.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f,
            active_lambda: full_count(&active_l),
            active_theta: active_t.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }

        // ---- joint CD for (D_Λ, D_Θ) ----
        let mut delta_l = SpRowMat::zeros(q, q);
        let mut delta_t = SpRowMat::zeros(p, q);
        let mut w = Mat::zeros(q, q);
        let mut vtp = Mat::zeros(q, p);
        prof.time("cd:joint", || {
            for _ in 0..opts.inner_sweeps {
                lambda_cd_pass(
                    &active_l,
                    &syy,
                    &sigma,
                    &psi,
                    &model.lambda,
                    &mut delta_l,
                    &mut w,
                    opts.lam_l,
                    Some(&JointTerms {
                        gamma_t: &gamma_t,
                        vtp: &vtp,
                    }),
                );
                theta_cd_pass_direction(
                    &active_t,
                    &sxx,
                    &sxx_diag,
                    &sxy,
                    &sigma,
                    &gamma,
                    &w,
                    &model.theta,
                    &mut delta_t,
                    &mut vtp,
                    opts.lam_t,
                );
            }
        });

        // ---- Armijo δ over the joint direction ----
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &delta_l);
        let mut tpd = model.theta.clone();
        tpd.add_scaled(1.0, &delta_t);
        let delta_armijo = trace_grad_dir(&gl, &delta_l)
            + trace_grad_dir(&gt, &delta_t)
            + opts.lam_l * (lpd.l1_norm() - model.lambda.l1_norm())
            + opts.lam_t * (tpd.l1_norm() - model.theta.l1_norm());
        if delta_armijo >= -1e-14 {
            // No usable descent direction: either converged (caught next
            // iteration by the screen) or numerically stuck.
            continue;
        }
        let (res, alpha) = prof.time("linesearch", || {
            joint_line_search(
                &obj,
                data,
                &model.lambda,
                &model.theta,
                &delta_l,
                &delta_t,
                &rt,
                f,
                &parts,
                delta_armijo,
                engine,
                &ls_opts,
            )
        })?;
        model.lambda.add_scaled(alpha, &delta_l);
        model.theta.add_scaled(alpha, &delta_t);
        model.lambda.prune(0.0);
        model.theta.prune(0.0);
        factor = res.factor;
        parts = res.parts;
        f = res.f_new;
        rt = data.xtheta_t(&model.theta);
        sigma = prof.time("sigma", || sigma_dense(&factor, engine, &par));
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    Ok(SolveResult { model, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn converges_on_tiny_chain() {
        let prob = datagen::chain::generate(10, 10, 60, 5);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 80,
            ..Default::default()
        };
        let res = solve(&prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged);
        let fs: Vec<f64> = res.trace.records.iter().map(|r| r.f).collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-9, "f increased: {fs:?}");
        }
    }
}
