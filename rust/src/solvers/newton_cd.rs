//! **Newton Coordinate Descent** — the previous state of the art
//! (Wytock & Kolter 2013; paper §2 "Optimization" + Appendix A.1), our
//! baseline system.
//!
//! One second-order model over the *joint* (Λ, Θ), minimized by coordinate
//! descent with the coupling terms:
//!
//! - precomputes `Γ = S_xxΘΣ` (the dense p×q matrix whose O(npq)
//!   construction the alternating method eliminates);
//! - Λ updates carry `-Φ_ij - Φ_ji`, `Φ = ΣΘᵀS_xxΔ_ΘΣ = Γᵀ V'`;
//! - Θ updates carry `+2Γ_ij - 2(ΓU)_ij` and cost O(p+q) each;
//! - one *joint* Armijo line search over (Λ + αD_Λ, Θ + αD_Θ).
//!
//! Statistics come cached from the [`SolverContext`]; all per-iteration
//! dense scratch (Σ, Ψ, Γ, Γᵀ, gradients, `U`/`V'` caches) is checked out
//! of the workspace arena — zero allocations in the iteration loop — and
//! every Λ factorization (including the joint line search's per-trial
//! factors) is tracked against the context's memory budget.
//!
//! Honors [`SolveOptions::screen`]: under a λ-path strong-rule restriction
//! the screens (and hence the joint CD work and the stopping statistic) are
//! confined to the allowed coordinate set — identical semantics to
//! `alt_newton_cd`'s restriction, with the KKT post-check in
//! `coordinator::solve_screened` guaranteeing equivalence.

use super::alt_newton_cd::{full_count, sigma_dense_into};
use super::cd_common::{
    lambda_cd_pass, lambda_cd_pass_colored, theta_cd_pass_direction,
    theta_cd_pass_direction_colored, trace_grad_dir, ColoredScratch, JointTerms,
};
use super::{SolveError, SolveOptions, SolveResult, SolverContext};
use crate::cggm::active::{
    lambda_active_dense, lambda_active_within, theta_active_dense, theta_active_within,
};
use crate::cggm::linesearch::{joint_line_search, LineSearchOptions};
use crate::cggm::objective::SmoothParts;
use crate::cggm::{CggmModel, Objective};
use crate::graph::coloring::ConflictSpace;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::timer::{PhaseProfiler, Stopwatch};

pub fn solve(
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
) -> Result<SolveResult, SolveError> {
    let data = ctx.data();
    let engine = ctx.engine();
    let ws = ctx.workspace();
    let par = ctx.par();
    let (p, q, n) = (data.p(), data.q(), data.n());
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let obj = Objective::new(data, opts.lam_l, opts.lam_t)
        .with_chol(opts.chol)
        .with_budget(ctx.budget().clone());
    let mut model = warm.cloned().unwrap_or_else(|| CggmModel::init(p, q));
    let mut trace = SolveTrace {
        solver: "newton_cd".into(),
        ..Default::default()
    };

    let syy = prof.time("cov:syy", || ctx.syy())?;
    let sxx = prof.time("cov:sxx", || ctx.sxx())?;
    let sxy = prof.time("cov:sxy", || ctx.sxy())?;
    let sxx_diag = ctx.sxx_diag();

    // Path-level strong-rule restriction (λ-path driver): screens and CD
    // work confined to the allowed coordinates.
    let screen = opts.screen.as_deref();

    // Colored parallel CD (`--cd-threads > 1`): conflict-free classes from
    // the context's churn-gated coloring caches, shared with alt_newton_cd.
    let cd_par = opts.cd_parallelism();
    let mut cd_scratch = ColoredScratch::default();

    let mut factor = obj.factor_lambda(&model.lambda, engine)?;
    let mut rt = ws.mat(q, n)?;
    data.xtheta_t_into(&model.theta, &mut rt);
    let mut parts = SmoothParts {
        logdet: factor.logdet(),
        tr_syy_lambda: obj.tr_syy_sparse(&model.lambda),
        tr_sxy_theta: obj.tr_sxy_sparse(&model.theta),
        tr_quad: factor.trace_quad(&rt),
    };
    let mut f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    let mut sigma = ws.mat(q, q)?;
    prof.time("sigma", || sigma_dense_into(&factor, engine, par, ws, &mut sigma))?;
    let ls_opts = LineSearchOptions::default();

    for it in 0..opts.max_iter {
        // ---- Γ, Ψ: the per-iteration dense precomputations (O(npq + nq²)) ----
        let mut psi = ws.mat(q, q)?;
        let mut gamma = ws.mat(p, q)?;
        {
            let mut sr = ws.mat(q, n)?;
            // Ψ from sr = Σ·rt; Γ = Xᵀ·sr/n reuses the same panel — one GEMM
            // saved versus recomputing Σ·rt.
            prof.time("psi", || obj.psi_into(&sigma, &rt, engine, &mut sr, &mut psi));
            prof.time("gamma", || {
                data.gemm_nt_x(engine, data.inv_n(), &sr, 0.0, &mut gamma);
            });
        }
        let mut gamma_t = ws.mat(q, p)?;
        prof.time("gamma", || gamma.transpose_into(&mut gamma_t));

        // ---- gradients & screens ----
        let mut gl = ws.mat(q, q)?;
        gl.copy_from(syy);
        gl.add_scaled(-1.0, &sigma);
        gl.add_scaled(-1.0, &psi);
        let mut gt = ws.mat(p, q)?;
        gt.copy_from(sxy);
        gt.add_scaled(1.0, &gamma);
        gt.scale(2.0);
        let (active_l, stats_l) = match screen {
            Some(set) => lambda_active_within(&gl, &model.lambda, opts.lam_l, &set.lambda),
            None => lambda_active_dense(&gl, &model.lambda, opts.lam_l),
        };
        let (active_t, stats_t) = match screen {
            Some(set) => {
                theta_active_within(|i, j| gt[(i, j)], &model.theta, opts.lam_t, &set.theta)
            }
            None => theta_active_dense(&gt, &model.theta, opts.lam_t),
        };
        trace.coords_screened += match screen {
            Some(set) => set.len(),
            None => q * (q + 1) / 2 + p * q,
        };
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = model.lambda.l1_norm() + model.theta.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f,
            active_lambda: full_count(&active_l),
            active_theta: active_t.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }
        if opts.cancel.is_cancelled() {
            return Err(SolveError::Cancelled);
        }
        trace.cd_updates += opts.inner_sweeps * (active_l.len() + active_t.len());

        // ---- joint CD for (D_Λ, D_Θ) ----
        let mut delta_l = SpRowMat::zeros(q, q);
        let mut delta_t = SpRowMat::zeros(p, q);
        let mut w = ws.mat(q, q)?;
        let mut vtp = ws.mat(q, p)?;
        prof.time("cd:joint", || -> Result<(), SolveError> {
            if opts.colored_cd() {
                let mut colorings = ctx.coloring_caches();
                // Split the RefMut once so both caches' class slices can
                // coexist (field-level borrows) without cloning either.
                let caches = &mut *colorings;
                let classes_l = caches.lambda.classes_for(
                    &active_l,
                    ConflictSpace::Symmetric(q),
                    opts.recluster_churn,
                    ctx.budget(),
                )?;
                let classes_t = caches.theta.classes_for(
                    &active_t,
                    ConflictSpace::Bipartite(p, q),
                    opts.recluster_churn,
                    ctx.budget(),
                )?;
                for _ in 0..opts.inner_sweeps {
                    lambda_cd_pass_colored(
                        classes_l,
                        syy,
                        &sigma,
                        &psi,
                        &model.lambda,
                        &mut delta_l,
                        &mut w,
                        opts.lam_l,
                        Some(&JointTerms {
                            gamma_t: &gamma_t,
                            vtp: &vtp,
                        }),
                        &cd_par,
                        &mut cd_scratch,
                    );
                    theta_cd_pass_direction_colored(
                        classes_t,
                        sxx,
                        sxx_diag,
                        sxy,
                        &sigma,
                        &gamma,
                        &w,
                        &model.theta,
                        &mut delta_t,
                        &mut vtp,
                        opts.lam_t,
                        &cd_par,
                        &mut cd_scratch,
                    );
                }
            } else {
                for _ in 0..opts.inner_sweeps {
                    lambda_cd_pass(
                        &active_l,
                        syy,
                        &sigma,
                        &psi,
                        &model.lambda,
                        &mut delta_l,
                        &mut w,
                        opts.lam_l,
                        Some(&JointTerms {
                            gamma_t: &gamma_t,
                            vtp: &vtp,
                        }),
                    );
                    theta_cd_pass_direction(
                        &active_t,
                        sxx,
                        sxx_diag,
                        sxy,
                        &sigma,
                        &gamma,
                        &w,
                        &model.theta,
                        &mut delta_t,
                        &mut vtp,
                        opts.lam_t,
                    );
                }
            }
            Ok(())
        })?;

        // ---- Armijo δ over the joint direction ----
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &delta_l);
        let mut tpd = model.theta.clone();
        tpd.add_scaled(1.0, &delta_t);
        let delta_armijo = trace_grad_dir(&gl, &delta_l)
            + trace_grad_dir(&gt, &delta_t)
            + opts.lam_l * (lpd.l1_norm() - model.lambda.l1_norm())
            + opts.lam_t * (tpd.l1_norm() - model.theta.l1_norm());
        if delta_armijo >= -1e-14 {
            // No usable descent direction: either converged (caught next
            // iteration by the screen) or numerically stuck.
            continue;
        }
        let (res, alpha) = prof.time("linesearch", || {
            joint_line_search(
                &obj,
                data,
                &model.lambda,
                &model.theta,
                &delta_l,
                &delta_t,
                &rt,
                f,
                &parts,
                delta_armijo,
                engine,
                &ls_opts,
            )
        })?;
        model.lambda.add_scaled(alpha, &delta_l);
        model.theta.add_scaled(alpha, &delta_t);
        model.lambda.prune(0.0);
        model.theta.prune(0.0);
        factor = res.factor;
        parts = res.parts;
        f = res.f_new;
        data.xtheta_t_into(&model.theta, &mut rt);
        prof.time("sigma", || sigma_dense_into(&factor, engine, par, ws, &mut sigma))?;
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    Ok(SolveResult { model, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn converges_on_tiny_chain() {
        let prob = datagen::chain::generate(10, 10, 60, 5);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 80,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let res = solve(&ctx, &opts, None).unwrap();
        assert!(res.trace.converged);
        let fs: Vec<f64> = res.trace.records.iter().map(|r| r.f).collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-9, "f increased: {fs:?}");
        }
    }
}
