//! **Accelerated proximal gradient (FISTA) baseline** — the other prior
//! approach the paper cites (Yuan & Zhang 2014 [11]; also OWL-QN [8] class).
//!
//! First-order method on the joint smooth part g(Λ,Θ) with the l1 prox:
//!
//! ```text
//! (Λ⁺, Θ⁺) = prox_{ηh}( y − η ∇g(y) ),   soft-threshold elementwise
//! ```
//!
//! with FISTA momentum, objective-restart, and backtracking on η that also
//! enforces Λ ≻ 0 (a failed Cholesky rejects the step). Dense iterates
//! (prox touches every coordinate), dense Γ each iteration — this is
//! exactly why second-order active-set methods win, and this solver exists
//! to measure that gap (`bench_solvers`, fig1c `--with-prox`).

use super::{SolveError, SolveOptions, SolveResult};
use crate::cggm::active::{lambda_active_dense, theta_active_dense};
use crate::cggm::soft_threshold;
use crate::cggm::{CggmModel, Dataset};
use crate::gemm::GemmEngine;
use crate::linalg::chol_dense::DenseChol;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::timer::{PhaseProfiler, Stopwatch};

/// Dense iterate (Λ, Θ).
#[derive(Clone)]
struct Iterate {
    lam: Mat,
    th: Mat,
}

struct SmoothEval {
    g: f64,
    grad_l: Mat,
    grad_t: Mat,
}

pub fn solve(
    data: &Dataset,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
) -> Result<SolveResult, SolveError> {
    let (p, q) = (data.p(), data.q());
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let mut trace = SolveTrace {
        solver: "prox_grad".into(),
        ..Default::default()
    };
    let syy = data.syy_dense(engine);
    let sxy = data.sxy_dense(engine);

    // Smooth part + gradients at a dense iterate (n-factored, no S_xx).
    let eval = |x: &Iterate| -> Option<SmoothEval> {
        let chol = DenseChol::factor(&x.lam, engine).ok()?;
        let sigma = chol.inverse(engine);
        // R̃ᵀ = Θᵀ·xt (q×n)
        let mut rtt = Mat::zeros(q, data.n());
        engine.gemm_tn(1.0, &x.th, &data.xt, 0.0, &mut rtt);
        let mut sr = Mat::zeros(q, data.n());
        engine.gemm(1.0, &sigma, &rtt, 0.0, &mut sr);
        let mut psi = Mat::zeros(q, q);
        engine.gemm_nt(data.inv_n(), &sr, &sr, 0.0, &mut psi);
        psi.symmetrize();
        let mut gamma = Mat::zeros(p, q);
        engine.gemm_nt(data.inv_n(), &data.xt, &sr, 0.0, &mut gamma);
        // g = -logdet + tr(SyyΛ) + 2tr(SxyᵀΘ) + tr(ΣΘᵀSxxΘ)
        let mut tr1 = 0.0;
        for (a, b) in syy.data().iter().zip(x.lam.data()) {
            tr1 += a * b;
        }
        let mut tr2 = 0.0;
        for (a, b) in sxy.data().iter().zip(x.th.data()) {
            tr2 += a * b;
        }
        // tr(ΣΘᵀSxxΘ) = tr(Γᵀ Θ) with Γ = SxxΘΣ ... = Σ_{ij} Γ_ij Θ_ij? No:
        // tr(ΘᵀSxxΘΣ) = Σ_ij Θ_ij (SxxΘΣ)_ij = <Θ, Γ>.
        let mut tr3 = 0.0;
        for (a, b) in gamma.data().iter().zip(x.th.data()) {
            tr3 += a * b;
        }
        let g = -chol.logdet() + tr1 + 2.0 * tr2 + tr3;
        let mut grad_l = syy.clone();
        grad_l.add_scaled(-1.0, &sigma);
        grad_l.add_scaled(-1.0, &psi);
        let mut grad_t = sxy.clone();
        grad_t.add_scaled(1.0, &gamma);
        grad_t.scale(2.0);
        Some(SmoothEval { g, grad_l, grad_t })
    };

    let prox = |y: &Iterate, ev: &SmoothEval, eta: f64| -> Iterate {
        let mut lam = Mat::zeros(q, q);
        for (o, (yi, gi)) in lam
            .data_mut()
            .iter_mut()
            .zip(y.lam.data().iter().zip(ev.grad_l.data()))
        {
            *o = soft_threshold(yi - eta * gi, eta * opts.lam_l);
        }
        lam.symmetrize();
        let mut th = Mat::zeros(p, q);
        for (o, (yi, gi)) in th
            .data_mut()
            .iter_mut()
            .zip(y.th.data().iter().zip(ev.grad_t.data()))
        {
            *o = soft_threshold(yi - eta * gi, eta * opts.lam_t);
        }
        Iterate { lam, th }
    };

    let penalty = |x: &Iterate| -> f64 {
        opts.lam_l * x.lam.data().iter().map(|v| v.abs()).sum::<f64>()
            + opts.lam_t * x.th.data().iter().map(|v| v.abs()).sum::<f64>()
    };

    let mut x = Iterate {
        lam: Mat::eye(q),
        th: Mat::zeros(p, q),
    };
    let mut y = x.clone();
    let mut tk = 1.0f64;
    let mut eta = 1.0f64;
    let mut ev_x = eval(&x).expect("Λ = I must be PD");
    let mut f_cur = ev_x.g + penalty(&x);

    for it in 0..opts.max_iter {
        // Trace + stopping statistic from the dense screens.
        let lam_sp = SpRowMat::from_dense(&x.lam, 0.0);
        let th_sp = SpRowMat::from_dense(&x.th, 0.0);
        let (al, stats_l) = lambda_active_dense(&ev_x.grad_l, &lam_sp, opts.lam_l);
        let (at, stats_t) = theta_active_dense(&ev_x.grad_t, &th_sp, opts.lam_t);
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = lam_sp.l1_norm() + th_sp.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f: f_cur,
            active_lambda: super::alt_newton_cd::full_count(&al),
            active_theta: at.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }

        // Momentum point (y already holds it; evaluate there).
        let ev_y = match prof.time("eval", || eval(&y)) {
            Some(e) => e,
            None => {
                // Momentum overshot the PD cone: restart from x.
                y = x.clone();
                tk = 1.0;
                eval(&y).expect("x is PD")
            }
        };
        // Backtracking on η: g(x⁺) ≤ g(y) + <∇g(y), x⁺−y> + ‖x⁺−y‖²/(2η).
        let mut accepted = None;
        for _ in 0..60 {
            let cand = prox(&y, &ev_y, eta);
            if let Some(ev_c) = eval(&cand) {
                let mut lin = 0.0;
                let mut dist2 = 0.0;
                for ((c, yv), g) in cand
                    .lam
                    .data()
                    .iter()
                    .zip(y.lam.data())
                    .zip(ev_y.grad_l.data())
                {
                    let d = c - yv;
                    lin += g * d;
                    dist2 += d * d;
                }
                for ((c, yv), g) in cand
                    .th
                    .data()
                    .iter()
                    .zip(y.th.data())
                    .zip(ev_y.grad_t.data())
                {
                    let d = c - yv;
                    lin += g * d;
                    dist2 += d * d;
                }
                if ev_c.g <= ev_y.g + lin + dist2 / (2.0 * eta) + 1e-12 {
                    accepted = Some((cand, ev_c));
                    break;
                }
            }
            eta *= 0.5;
        }
        let (x_new, ev_new) = match accepted {
            Some(v) => v,
            None => break, // η underflow — numerically stuck
        };
        let f_new = ev_new.g + penalty(&x_new);
        // FISTA momentum with function restart.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * tk * tk).sqrt());
        if f_new > f_cur {
            // restart
            y = x_new.clone();
            tk = 1.0;
        } else {
            let beta = (tk - 1.0) / t_next;
            let mut ynew = x_new.clone();
            ynew.lam.scale(1.0 + beta);
            ynew.lam.add_scaled(-beta, &x.lam);
            ynew.th.scale(1.0 + beta);
            ynew.th.add_scaled(-beta, &x.th);
            y = ynew;
            tk = t_next;
        }
        x = x_new;
        ev_x = ev_new;
        f_cur = f_new;
        // Gentle η growth so backtracking can recover.
        eta *= 1.1;
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    let mut model = CggmModel::init(p, q);
    model.lambda = SpRowMat::from_dense(&x.lam, 0.0);
    model.theta = SpRowMat::from_dense(&x.th, 0.0);
    Ok(SolveResult { model, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;
    use crate::solvers::{solve as dispatch, SolverKind};

    #[test]
    fn reaches_the_same_optimum_as_alt_newton() {
        let prob = datagen::chain::generate(10, 10, 80, 3);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.3,
            lam_t: 0.3,
            max_iter: 800,
            tol: 0.01,
            ..Default::default()
        };
        let fista = solve(&prob.data, &opts, &eng).unwrap();
        let alt = dispatch(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
        let (ff, fa) = (
            fista.trace.final_f().unwrap(),
            alt.trace.final_f().unwrap(),
        );
        assert!(
            (ff - fa).abs() < 5e-3 * fa.abs().max(1.0),
            "fista {ff} vs alt {fa}"
        );
        // (On tiny well-conditioned problems FISTA can be iteration-
        // competitive; the gap appears at scale — see bench_solvers.)
        eprintln!(
            "iters: fista {} vs alt {}",
            fista.trace.records.len(),
            alt.trace.records.len()
        );
    }

    #[test]
    fn lambda_iterates_stay_pd() {
        let prob = datagen::chain::generate(8, 8, 50, 9);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 100,
            ..Default::default()
        };
        let res = solve(&prob.data, &opts, &eng).unwrap();
        // Final Λ factorizes.
        assert!(DenseChol::factor(&res.model.lambda.to_dense(), &eng).is_ok());
        assert!(res.trace.final_f().unwrap().is_finite());
    }
}
