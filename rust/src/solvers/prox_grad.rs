//! **Accelerated proximal gradient (FISTA) baseline** — the other prior
//! approach the paper cites (Yuan & Zhang 2014 [11]; also OWL-QN [8] class).
//!
//! First-order method on the joint smooth part g(Λ,Θ) with the l1 prox:
//!
//! ```text
//! (Λ⁺, Θ⁺) = prox_{ηh}( y − η ∇g(y) ),   soft-threshold elementwise
//! ```
//!
//! with FISTA momentum, objective-restart, and backtracking on η that also
//! enforces Λ ≻ 0 (a failed Cholesky rejects the step). Dense iterates
//! (prox touches every coordinate), dense Γ each iteration — this is
//! exactly why second-order active-set methods win, and this solver exists
//! to measure that gap (`bench_solvers`, fig1c `--with-prox`).
//!
//! `S_yy`/`S_xy` come cached from the [`SolverContext`] (this solver is
//! n-factored and never forms the p×p `S_xx`); the dense iterates, momentum
//! point, prox candidate, and every smooth-evaluation scratch matrix are
//! workspace-arena checkouts, so the FISTA loop — including its inner
//! backtracking trials — performs no allocations. Each smooth evaluation's
//! dense Cholesky (one per backtracking trial) registers its bytes against
//! the budget for the duration of the evaluation, so `MemBudget::peak()`
//! covers the factorization scratch here too.
//!
//! Honors [`SolveOptions::screen`]: under a λ-path strong-rule restriction
//! the prox step only moves allowed coordinates (everything else stays
//! frozen — zero from a cold start, the warm support having been merged into
//! the set by `coordinator::solve_screened`), and the screens/stopping
//! statistic are confined to the same set.

use super::workspace::{Workspace, WsMat};
use super::{SolveError, SolveOptions, SolveResult, SolverContext};
use crate::cggm::active::{
    lambda_active_dense, lambda_active_within, theta_active_dense, theta_active_within,
    ScreenSet,
};
use crate::cggm::factor::{dense_factor_bytes, dense_factor_scratch_bytes, FactorError};
use crate::cggm::soft_threshold;
use crate::cggm::{CggmModel, Dataset};
use crate::gemm::GemmEngine;
use crate::linalg::chol_dense::DenseChol;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::threadpool::Parallelism;
use crate::util::timer::{PhaseProfiler, Stopwatch};

/// Smooth value + gradients at one iterate; the gradient buffers stay
/// checked out of the arena while the eval is alive.
struct SmoothEval<'w> {
    g: f64,
    grad_l: WsMat<'w>,
    grad_t: WsMat<'w>,
}

/// g, ∇_Λg, ∇_Θg at (Λ, Θ). `Ok(None)` means Λ ⊁ 0 (momentum overshot the
/// PD cone); `Err` is a budget failure.
#[allow(clippy::too_many_arguments)]
fn eval_smooth<'w>(
    ws: &'w Workspace,
    data: &Dataset,
    syy: &Mat,
    sxy: &Mat,
    engine: &dyn GemmEngine,
    par: &Parallelism,
    lam: &Mat,
    th: &Mat,
) -> Result<Option<SmoothEval<'w>>, SolveError> {
    let (p, q, n) = (data.p(), data.q(), data.n());
    // The factor lives for this evaluation only; register its resident L and
    // the blocked factorization's scratch against the budget for exactly
    // that long (the per-trial factor bytes the memwall numbers must see).
    let _factor_bytes = ws
        .budget()
        .track(dense_factor_bytes(q) + dense_factor_scratch_bytes(q))?;
    let chol = match DenseChol::factor(lam, engine) {
        Ok(c) => c,
        Err(_) => return Ok(None),
    };
    let mut sigma = ws.mat(q, q)?;
    {
        let mut wtri = ws.mat(q, q)?;
        chol.inverse_into_scratch_par(engine, par, &mut wtri, &mut sigma);
    }
    // R̃ᵀ = Θᵀ·xt (q×n); sr = Σ·R̃ᵀ.
    let mut rtt = ws.mat(q, n)?;
    data.gemm_tn_x(engine, 1.0, th, 0.0, &mut rtt);
    let mut sr = ws.mat(q, n)?;
    engine.gemm(1.0, &sigma, &rtt, 0.0, &mut sr);
    let mut psi = ws.mat(q, q)?;
    engine.gemm_nt(data.inv_n(), &sr, &sr, 0.0, &mut psi);
    psi.symmetrize();
    let mut gamma = ws.mat(p, q)?;
    data.gemm_nt_x(engine, data.inv_n(), &sr, 0.0, &mut gamma);
    // g = -logdet + tr(SyyΛ) + 2tr(SxyᵀΘ) + tr(ΣΘᵀSxxΘ), the last term as
    // tr(ΘᵀSxxΘΣ) = Σ_ij Θ_ij (SxxΘΣ)_ij = <Θ, Γ>.
    let mut tr1 = 0.0;
    for (a, b) in syy.data().iter().zip(lam.data()) {
        tr1 += a * b;
    }
    let mut tr2 = 0.0;
    for (a, b) in sxy.data().iter().zip(th.data()) {
        tr2 += a * b;
    }
    let mut tr3 = 0.0;
    for (a, b) in gamma.data().iter().zip(th.data()) {
        tr3 += a * b;
    }
    let g = -chol.logdet() + tr1 + 2.0 * tr2 + tr3;
    let mut grad_l = ws.mat(q, q)?;
    grad_l.copy_from(syy);
    grad_l.add_scaled(-1.0, &sigma);
    grad_l.add_scaled(-1.0, &psi);
    let mut grad_t = ws.mat(p, q)?;
    grad_t.copy_from(sxy);
    grad_t.add_scaled(1.0, &gamma);
    grad_t.scale(2.0);
    Ok(Some(SmoothEval { g, grad_l, grad_t }))
}

/// Dense membership masks for a screen set: full q×q for Λ (both triangles)
/// and p×q for Θ. Built once per solve; the prox step reads them per
/// coordinate.
fn screen_masks(set: &ScreenSet, p: usize, q: usize) -> (Vec<bool>, Vec<bool>) {
    let mut ml = vec![false; q * q];
    for &(i, j) in &set.lambda {
        ml[i * q + j] = true;
        ml[j * q + i] = true;
    }
    let mut mt = vec![false; p * q];
    for &(i, j) in &set.theta {
        mt[i * q + j] = true;
    }
    (ml, mt)
}

/// (Λ⁺, Θ⁺) = prox_{ηh}(y − η∇g(y)), written into `out_*`. With `masks`,
/// only allowed coordinates take the gradient-prox step; the rest copy `y`
/// unchanged — since frozen coordinates never move, their momentum point
/// equals their (frozen) value, so copying `y` keeps them exactly fixed.
/// Row-parallel under `par` (prox touches every coordinate — this is this
/// solver's per-iteration coordinate hot loop, so it follows
/// `SolveOptions::cd_threads`); rows are disjoint output chunks, so the
/// result is thread-count-independent.
#[allow(clippy::too_many_arguments)]
fn prox_step(
    y_lam: &Mat,
    y_th: &Mat,
    ev: &SmoothEval,
    eta: f64,
    lam_l: f64,
    lam_t: f64,
    masks: Option<&(Vec<bool>, Vec<bool>)>,
    par: &Parallelism,
    out_lam: &mut Mat,
    out_th: &mut Mat,
) {
    let (ml, mt) = match masks {
        Some((ml, mt)) => (Some(ml.as_slice()), Some(mt.as_slice())),
        None => (None, None),
    };
    // Hoist plain data slices: the parallel closures must not capture the
    // workspace-backed guards (the arena is single-owner, not Sync).
    let q = y_lam.cols();
    let (yl, gl) = (y_lam.data(), ev.grad_l.data());
    par.parallel_chunks_mut(out_lam.data_mut(), q, |i, orow| {
        let base = i * q;
        for (k, o) in orow.iter_mut().enumerate() {
            *o = match ml {
                Some(mask) if !mask[base + k] => yl[base + k],
                _ => soft_threshold(yl[base + k] - eta * gl[base + k], eta * lam_l),
            };
        }
    });
    out_lam.symmetrize();
    let qt = y_th.cols();
    let (yt, gt) = (y_th.data(), ev.grad_t.data());
    par.parallel_chunks_mut(out_th.data_mut(), qt, |i, orow| {
        let base = i * qt;
        for (k, o) in orow.iter_mut().enumerate() {
            *o = match mt {
                Some(mask) if !mask[base + k] => yt[base + k],
                _ => soft_threshold(yt[base + k] - eta * gt[base + k], eta * lam_t),
            };
        }
    });
}

pub fn solve(
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
) -> Result<SolveResult, SolveError> {
    let data = ctx.data();
    let engine = ctx.engine();
    let ws = ctx.workspace();
    let par = ctx.par();
    let cd_par = opts.cd_parallelism();
    let (p, q) = (data.p(), data.q());
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let mut trace = SolveTrace {
        solver: "prox_grad".into(),
        ..Default::default()
    };
    let syy = ctx.syy()?;
    let sxy = ctx.sxy()?;

    // Path-level strong-rule restriction: masks for the prox step (built
    // once), restricted screens for the stopping statistic.
    let screen = opts.screen.as_deref();
    let masks = screen.map(|set| screen_masks(set, p, q));

    let penalty = |lam: &Mat, th: &Mat| -> f64 {
        opts.lam_l * lam.data().iter().map(|v| v.abs()).sum::<f64>()
            + opts.lam_t * th.data().iter().map(|v| v.abs()).sum::<f64>()
    };

    // Dense iterates x (current), y (momentum point), cand (prox trial) —
    // six arena buffers that live for the whole solve.
    let mut x_lam = ws.mat(q, q)?;
    let mut x_th = ws.mat(p, q)?;
    match warm {
        Some(m) => {
            // Scatter the sparse rows straight into the zeroed arena buffers
            // (no untracked dense temporaries).
            for i in 0..q {
                for &(j, v) in m.lambda.row(i) {
                    x_lam[(i, j)] = v;
                }
            }
            for i in 0..p {
                for &(j, v) in m.theta.row(i) {
                    x_th[(i, j)] = v;
                }
            }
        }
        None => {
            for i in 0..q {
                x_lam[(i, i)] = 1.0;
            }
        }
    }
    let mut y_lam = ws.mat(q, q)?;
    let mut y_th = ws.mat(p, q)?;
    y_lam.copy_from(&x_lam);
    y_th.copy_from(&x_th);
    let mut cand_lam = ws.mat(q, q)?;
    let mut cand_th = ws.mat(p, q)?;

    let mut tk = 1.0f64;
    let mut eta = 1.0f64;
    // A non-PD initial Λ (possible with a caller-supplied warm start) is an
    // error, not a panic — same contract as the factorizing solvers.
    let mut ev_x = match eval_smooth(ws, data, syy, sxy, engine, par, &x_lam, &x_th)? {
        Some(e) => e,
        None => return Err(SolveError::Factor(FactorError::NotPd)),
    };
    let mut f_cur = ev_x.g + penalty(&x_lam, &x_th);

    for it in 0..opts.max_iter {
        // Trace + stopping statistic from the (possibly restricted) screens.
        let lam_sp = SpRowMat::from_dense(&x_lam, 0.0);
        let th_sp = SpRowMat::from_dense(&x_th, 0.0);
        let (al, stats_l) = match screen {
            Some(set) => lambda_active_within(&ev_x.grad_l, &lam_sp, opts.lam_l, &set.lambda),
            None => lambda_active_dense(&ev_x.grad_l, &lam_sp, opts.lam_l),
        };
        let (at, stats_t) = match screen {
            Some(set) => {
                theta_active_within(|i, j| ev_x.grad_t[(i, j)], &th_sp, opts.lam_t, &set.theta)
            }
            None => theta_active_dense(&ev_x.grad_t, &th_sp, opts.lam_t),
        };
        trace.coords_screened += match screen {
            Some(set) => set.len(),
            None => q * (q + 1) / 2 + p * q,
        };
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = lam_sp.l1_norm() + th_sp.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f: f_cur,
            active_lambda: super::alt_newton_cd::full_count(&al),
            active_theta: at.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }
        if opts.cancel.is_cancelled() {
            return Err(SolveError::Cancelled);
        }

        // Momentum point (y already holds it; evaluate there).
        let ev_y = match prof.time("eval", || {
            eval_smooth(ws, data, syy, sxy, engine, par, &y_lam, &y_th)
        })? {
            Some(e) => e,
            None => {
                // Momentum overshot the PD cone: restart from x.
                y_lam.copy_from(&x_lam);
                y_th.copy_from(&x_th);
                tk = 1.0;
                eval_smooth(ws, data, syy, sxy, engine, par, &y_lam, &y_th)?.expect("x is PD")
            }
        };
        // Backtracking on η: g(x⁺) ≤ g(y) + <∇g(y), x⁺−y> + ‖x⁺−y‖²/(2η).
        let mut accepted: Option<SmoothEval> = None;
        for _ in 0..60 {
            prox_step(
                &y_lam,
                &y_th,
                &ev_y,
                eta,
                opts.lam_l,
                opts.lam_t,
                masks.as_ref(),
                &cd_par,
                &mut cand_lam,
                &mut cand_th,
            );
            if let Some(ev_c) =
                eval_smooth(ws, data, syy, sxy, engine, par, &cand_lam, &cand_th)?
            {
                let mut lin = 0.0;
                let mut dist2 = 0.0;
                for ((c, yv), g) in cand_lam
                    .data()
                    .iter()
                    .zip(y_lam.data())
                    .zip(ev_y.grad_l.data())
                {
                    let d = c - yv;
                    lin += g * d;
                    dist2 += d * d;
                }
                for ((c, yv), g) in cand_th
                    .data()
                    .iter()
                    .zip(y_th.data())
                    .zip(ev_y.grad_t.data())
                {
                    let d = c - yv;
                    lin += g * d;
                    dist2 += d * d;
                }
                if ev_c.g <= ev_y.g + lin + dist2 / (2.0 * eta) + 1e-12 {
                    accepted = Some(ev_c);
                    break;
                }
            }
            eta *= 0.5;
        }
        let ev_new = match accepted {
            Some(v) => v,
            None => break, // η underflow — numerically stuck
        };
        // Prox "update" work: one pass over every coordinate the step may
        // move (the restricted set under screening, all of them otherwise).
        trace.cd_updates += match screen {
            Some(set) => set.len(),
            None => q * q + p * q,
        };
        let f_new = ev_new.g + penalty(&cand_lam, &cand_th);
        // FISTA momentum with function restart.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * tk * tk).sqrt());
        if f_new > f_cur {
            // restart
            y_lam.copy_from(&cand_lam);
            y_th.copy_from(&cand_th);
            tk = 1.0;
        } else {
            let beta = (tk - 1.0) / t_next;
            // y = (1+β)·x_new − β·x_old, in place.
            y_lam.copy_from(&cand_lam);
            y_lam.scale(1.0 + beta);
            y_lam.add_scaled(-beta, &x_lam);
            y_th.copy_from(&cand_th);
            y_th.scale(1.0 + beta);
            y_th.add_scaled(-beta, &x_th);
            tk = t_next;
        }
        // x ← x_new by swapping buffers (cand becomes the stale pair).
        std::mem::swap(&mut x_lam, &mut cand_lam);
        std::mem::swap(&mut x_th, &mut cand_th);
        ev_x = ev_new;
        f_cur = f_new;
        // Gentle η growth so backtracking can recover.
        eta *= 1.1;
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    let mut model = CggmModel::init(p, q);
    model.lambda = SpRowMat::from_dense(&x_lam, 0.0);
    model.theta = SpRowMat::from_dense(&x_th, 0.0);
    Ok(SolveResult { model, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;
    use crate::solvers::{solve as dispatch, SolverKind};

    #[test]
    fn reaches_the_same_optimum_as_alt_newton() {
        let prob = datagen::chain::generate(10, 10, 80, 3);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.3,
            lam_t: 0.3,
            max_iter: 800,
            tol: 0.01,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let fista = solve(&ctx, &opts, None).unwrap();
        let alt = dispatch(SolverKind::AltNewtonCd, &prob.data, &opts, &eng).unwrap();
        let (ff, fa) = (
            fista.trace.final_f().unwrap(),
            alt.trace.final_f().unwrap(),
        );
        assert!(
            (ff - fa).abs() < 5e-3 * fa.abs().max(1.0),
            "fista {ff} vs alt {fa}"
        );
        // (On tiny well-conditioned problems FISTA can be iteration-
        // competitive; the gap appears at scale — see bench_solvers.)
        eprintln!(
            "iters: fista {} vs alt {}",
            fista.trace.records.len(),
            alt.trace.records.len()
        );
    }

    #[test]
    fn lambda_iterates_stay_pd() {
        let prob = datagen::chain::generate(8, 8, 50, 9);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 100,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let res = solve(&ctx, &opts, None).unwrap();
        // Final Λ factorizes.
        assert!(DenseChol::factor(&res.model.lambda.to_dense(), &eng).is_ok());
        assert!(res.trace.final_f().unwrap().is_finite());
    }
}
