//! **Algorithm 2 — Alternating Newton Block Coordinate Descent** (paper §4).
//!
//! Algorithm 1 restructured so that no dense q×q or p×p matrix is ever
//! materialized:
//!
//! - **Σ columns by conjugate gradient** (`Λσ_t = e_t`, Jacobi-preconditioned,
//!   K ≈ 10–40) — computed per block and cached under the memory budget;
//! - **Ψ columns** via `ψ_t = Λ⁻¹(ΘᵀS_xxΘ)σ_t = (1/n)·Λ⁻¹ R̃ᵀ(R̃σ_t)` with
//!   `R̃ = XΘ` (n×q) — one extra CG per column, no p×q intermediates;
//! - **graph clustering** (S7, METIS substitute) picks the partition
//!   {C_1..C_k} that minimizes active entries in off-diagonal blocks, so
//!   off-diagonal column loads (the cache misses, B = Σ|B_zr|) stay rare;
//! - **Θ row-blocks** (§4.2): one row of S_xx at a time, restricted to the
//!   union of non-empty Θ rows and active rows (row-wise sparsity), with
//!   `V = ΘΣ_{C_r}` maintained per block;
//! - the **memory budget** ([`crate::util::membudget::MemBudget`]) chooses
//!   k_Λ, k_Θ ("the smallest possible k such that we can store 2q/k columns
//!   in memory") and every cache allocation is tracked against it, which is
//!   how the paper's OOM wall is reproduced on a large-RAM machine.
//!
//! All block caches and GEMM panels are checked out of the
//! [`SolverContext`]'s workspace arena, and the Λ factorizations (line-search
//! trials included) are budget-tracked, so buffers recycle across blocks and
//! iterations and `MemBudget::peak()` is the measured truth the `memwall`
//! experiment reports — now covering every byte. This solver deliberately
//! never touches the context's dense `S_yy`/`S_xx`/`S_xy` caches — their
//! absence *is* Algorithm 2.
//!
//! The graph-clustering partitions for the Λ column blocks and Θ output
//! blocks persist in the [`SolverContext`] across outer iterations and
//! adjacent λ-path points ([`crate::graph::cluster::PersistentPartition`]):
//! supports change slowly along a path, so the partition is rebuilt only
//! when active-set churn crosses [`SolveOptions::recluster_churn`] (observable
//! via `SolveTrace::reclusterings`).

use super::workspace::{Workspace, WsMat};
use super::{SolveError, SolveOptions, SolveResult, SolverContext};
use crate::cggm::factor::{FactorRepr, LambdaFactor};
use crate::cggm::linesearch::{lambda_line_search, LineSearchOptions};
use crate::cggm::objective::{min_norm_subgrad, SmoothParts};
use crate::cggm::tiles::TileStore;
use crate::cggm::{cd_minimizer, CggmModel, Dataset, Objective};
use crate::gemm::GemmEngine;
use crate::graph::cluster::{
    contiguous_blocks, ClusterOptions, PersistentPartition,
};
use crate::graph::coloring::{greedy_color, ConflictSpace};
use crate::graph::Graph;
use crate::linalg::cg::CgSolver;
use crate::linalg::dense::{axpy, dot, Mat};
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::threadpool::{Parallelism, SharedMut, SharedSlice};
use crate::util::timer::{PhaseProfiler, Stopwatch};

const CG_TOL: f64 = 1e-10;

/// Source of Σ columns (and Ψ back-solves).
///
/// The paper's Algorithm 2 uses conjugate gradient so that no factor of Λ
/// need ever be stored. We keep CG as the guaranteed-memory path, but when
/// the sparse Cholesky factor computed by the *line search* (whose fill is
/// known) fits comfortably in the budget, its triangular solves are an
/// order of magnitude cheaper than K CG iterations — the paper itself
/// remarks that "sparse Cholesky decomposition exploits sparsity"
/// (EXPERIMENTS.md §Perf iter 2).
pub(crate) enum SigmaOracle<'a> {
    Cg(&'a CgSolver),
    Chol(&'a crate::linalg::chol_sparse::SparseChol),
}

impl SigmaOracle<'_> {
    fn n(&self) -> usize {
        match self {
            SigmaOracle::Cg(cg) => cg.n(),
            SigmaOracle::Chol(f) => f.n(),
        }
    }

    fn solve_into(&self, b: &[f64], out: &mut [f64]) {
        match self {
            SigmaOracle::Cg(cg) => {
                cg.solve(b, out);
            }
            SigmaOracle::Chol(f) => out.copy_from_slice(&f.solve(b)),
        }
    }

    /// σ_t = Λ⁻¹ e_t.
    fn unit_column(&self, t: usize, out: &mut [f64]) {
        let mut e = vec![0.0; self.n()];
        e[t] = 1.0;
        // Zero warm start for CG.
        if matches!(self, SigmaOracle::Cg(_)) {
            out.iter_mut().for_each(|x| *x = 0.0);
        }
        self.solve_into(&e, out);
    }
}

/// Pick the Σ oracle: the current Λ-factor when it is sparse and its fill
/// fits in a quarter of the budget, else CG.
fn pick_sigma<'a>(
    factor: &'a LambdaFactor,
    cg: &'a CgSolver,
    opts: &SolveOptions,
) -> SigmaOracle<'a> {
    if let FactorRepr::Sparse(f) = factor.repr() {
        // The factor's bytes are already registered against the budget
        // (factor_tracked); using it as the Σ oracle adds no new memory, so
        // the only question is whether keeping it hot is comfortable.
        let bytes = f.nnz() * 16;
        if bytes <= opts.budget.limit() / 4 || bytes <= opts.budget.available() {
            return SigmaOracle::Chol(f);
        }
    }
    SigmaOracle::Cg(cg)
}

/// An active Λ coordinate with its screened gradient value.
#[derive(Clone, Copy, Debug)]
struct ActivePair {
    i: usize,
    j: usize,
    grad: f64,
}

/// Cached columns for one Λ block: row c of each matrix corresponds to
/// global column `cols[c]`. The three column matrices are workspace
/// checkouts, tracked against the budget for as long as the cache is alive.
struct LambdaCache<'w> {
    cols: Vec<usize>,
    /// σ_t = Λ⁻¹ e_t, full q-vectors.
    sigma: WsMat<'w>,
    /// ψ_t = Λ⁻¹ΘᵀS_xxΘσ_t, full q-vectors.
    psi: WsMat<'w>,
    /// u_t = Δ_Λ σ_t (maintained through CD updates).
    u: WsMat<'w>,
}

pub fn solve(
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
) -> Result<SolveResult, SolveError> {
    let data = ctx.data();
    let engine = ctx.engine();
    let ws = ctx.workspace();
    let par = ctx.par();
    let (p, q, n) = (data.p(), data.q(), data.n());
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let obj = Objective::new(data, opts.lam_l, opts.lam_t)
        .with_chol(opts.chol)
        .with_budget(ctx.budget().clone());
    let mut model = warm.cloned().unwrap_or_else(|| CggmModel::init(p, q));
    let mut trace = SolveTrace {
        solver: "alt_newton_bcd".into(),
        ..Default::default()
    };

    let mut factor = obj.factor_lambda(&model.lambda, engine)?;
    let mut rt = ws.mat(q, n)?; // R̃ᵀ (q×n)
    data.xtheta_t_into(&model.theta, &mut rt);
    let mut parts = SmoothParts {
        logdet: factor.logdet(),
        tr_syy_lambda: obj.tr_syy_sparse(&model.lambda),
        tr_sxy_theta: obj.tr_sxy_sparse(&model.theta),
        tr_quad: factor.trace_quad(&rt),
    };
    let mut f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    let ls_opts = LineSearchOptions::default();
    // Reusable column-position lookup (usize::MAX = not cached).
    let mut pos: Vec<usize> = vec![usize::MAX; q.max(p)];

    // Strong-rule restriction (SolveOptions::screen): per-column Λ row
    // lists and per-row Θ column lists, so the blockwise screens — and
    // hence all CD work and the stopping statistic — only touch allowed
    // coordinates. Blocks whose columns have no allowed entries skip their
    // σ/ψ column loads entirely. Built once per solve; O(|set|) memory,
    // respecting this solver's no-dense-matrices story.
    let screen = opts.screen.as_deref();
    let lambda_allowed: Option<Vec<Vec<usize>>> = screen.map(|set| {
        let mut by_col: Vec<Vec<usize>> = vec![Vec::new(); q];
        for &(i, j) in &set.lambda {
            by_col[j].push(i); // i ≤ j by ScreenSet convention
        }
        by_col
    });
    let theta_allowed: Option<Vec<Vec<usize>>> = screen.map(|set| {
        let mut by_row: Vec<Vec<usize>> = vec![Vec::new(); p];
        for &(i, j) in &set.theta {
            by_row[i].push(j); // row-major sorted by construction
        }
        by_row
    });

    // Colored parallel CD (`--cd-threads > 1`) for the panel sweeps.
    let cd_par = opts.cd_parallelism();

    for it in 0..opts.max_iter {
        let cg = CgSolver::new(model.lambda.to_csr(), CG_TOL, 20 * q.max(16));
        let sig = pick_sigma(&factor, &cg, opts);

        // ================= Λ phase =================
        // ---- screen: blockwise gradient of Λ (O(nq²), GEMM-backed) ----
        let screen_bsz = lambda_screen_block(q, n, opts);
        let mut active: Vec<ActivePair> = Vec::new();
        let mut subgrad_l = 0.0;
        // Perf iter 3 (EXPERIMENTS.md §Perf): when the whole column range
        // fits in one screen block AND the CD partition will be a single
        // block, the screen's σ/ψ columns are exactly what the sweep needs —
        // keep them instead of recomputing (u is zero because Δ starts at 0).
        let mut screen_cache: Option<LambdaCache> = None;
        prof.time("screen:lambda", || -> Result<(), SolveError> {
            let mut t0 = 0;
            while t0 < q {
                let bsz = screen_bsz.min(q - t0);
                // Under a restriction, only load σ/ψ for columns with
                // allowed coordinates — the screening win the strong rule
                // buys this solver.
                let cols: Vec<usize> = match &lambda_allowed {
                    Some(by_col) => (t0..t0 + bsz)
                        .filter(|&t| !by_col[t].is_empty())
                        .collect(),
                    None => (t0..t0 + bsz).collect(),
                };
                t0 += bsz;
                if cols.is_empty() {
                    continue;
                }
                let m = cols.len();
                let cache = load_lambda_cache(
                    data, &sig, &rt, &SpRowMat::zeros(q, q), &cols, par, ws,
                )?;
                // S_yy block = gemm_nt(yt, yt[cols]) / n  (q×m).
                let mut ytb = ws.mat(m, n)?;
                data.y_rows_into(&cols, &mut ytb);
                let mut syyb = ws.mat(q, m)?;
                data.gemm_nt_y(engine, data.inv_n(), &ytb, 0.0, &mut syyb);
                for (c, &t) in cols.iter().enumerate() {
                    let sigc = cache.sigma.row(c);
                    let psic = cache.psi.row(c);
                    let mut scan = |i: usize| {
                        let g = syyb[(i, c)] - sigc[i] - psic[i];
                        let x = model.lambda.get(i, t);
                        let s = min_norm_subgrad(g, x, opts.lam_l);
                        subgrad_l += if i == t { s.abs() } else { 2.0 * s.abs() };
                        if x != 0.0 || g.abs() > opts.lam_l {
                            active.push(ActivePair { i, j: t, grad: g });
                        }
                    };
                    match &lambda_allowed {
                        Some(by_col) => by_col[t].iter().for_each(|&i| scan(i)),
                        None => (0..=t).for_each(scan),
                    }
                }
                if m == q {
                    screen_cache = Some(cache);
                }
            }
            Ok(())
        })?;

        // ---- Θ screen (also needed for the stopping statistic) ----
        let (theta_active, subgrad_t) = prof.time("screen:theta", || {
            theta_screen(
                data,
                &sig,
                &model,
                engine,
                par,
                opts,
                ws,
                theta_allowed.as_deref(),
                ctx.tiles(),
            )
        })?;
        trace.coords_screened += match screen {
            Some(set) => set.len(),
            None => q * (q + 1) / 2 + p * q,
        };

        let subgrad = subgrad_l + subgrad_t;
        let param_l1 = model.lambda.l1_norm() + model.theta.l1_norm();
        let active_l_count = active
            .iter()
            .map(|a| if a.i == a.j { 1 } else { 2 })
            .sum::<usize>();
        let active_t_count: usize = theta_active.iter().map(|(_, v)| v.len()).sum();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f,
            active_lambda: active_l_count,
            active_theta: active_t_count,
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }
        if opts.cancel.is_cancelled() {
            return Err(SolveError::Cancelled);
        }

        // ---- partition columns of Λ (graph clustering on the active set,
        // persisted in the context and rebuilt only on churn) ----
        let k_l = lambda_block_count(q, n, opts);
        let blocks: Vec<Vec<usize>> = prof.time("cluster:lambda", || {
            if opts.clustering && k_l > 1 {
                let mut sig: Vec<(usize, usize)> = active
                    .iter()
                    .filter(|a| a.i != a.j)
                    .map(|a| (a.i.min(a.j), a.i.max(a.j)))
                    .collect();
                sig.sort_unstable();
                sig.dedup();
                let mut caches = ctx.cluster_caches();
                let (blocks, reclustered) = caches.lambda.blocks_cached(
                    q,
                    k_l,
                    &ClusterOptions {
                        seed: opts.seed,
                        ..Default::default()
                    },
                    sig,
                    opts.recluster_churn,
                    || {
                        let mut g = Graph::empty(q);
                        for a in &active {
                            if a.i != a.j {
                                g.add_edge(a.i, a.j, 1.0);
                            }
                        }
                        g
                    },
                );
                if reclustered {
                    trace.reclusterings += 1;
                }
                blocks
            } else {
                contiguous_blocks(q, k_l)
            }
        });
        // Bucket active pairs by unordered block pair.
        let mut block_of = vec![0usize; q];
        for (b, cols) in blocks.iter().enumerate() {
            for &c in cols {
                block_of[c] = b;
            }
        }
        let nb = blocks.len();
        let mut buckets: Vec<Vec<ActivePair>> = vec![Vec::new(); nb * nb];
        for a in &active {
            let (x, y) = (
                block_of[a.i].min(block_of[a.j]),
                block_of[a.i].max(block_of[a.j]),
            );
            buckets[x * nb + y].push(*a);
        }

        // ---- blocked CD for the Newton direction D_Λ ----
        // With `--cd-threads > 1`, each bucket's pairs are greedily colored
        // into index-disjoint classes once per iteration and swept by the
        // parallel panel variant.
        let colored_buckets: Option<Vec<Vec<Vec<ActivePair>>>> = if opts.colored_cd() {
            Some(buckets.iter().map(|b| color_bucket(b, q)).collect())
        } else {
            None
        };
        let mut delta = SpRowMat::zeros(q, q);
        prof.time("cd:lambda", || -> Result<(), SolveError> {
            for sweep in 0..opts.inner_sweeps {
                for z in 0..nb {
                    // Load the z-block cache once; reuse across all r.
                    // (Perf iter 3: first single-block sweep reuses the
                    // screen's columns — Δ = 0 so u = 0 matches.)
                    let mut cz = match (nb, sweep, screen_cache.take()) {
                        (1, 0, Some(c)) => c,
                        _ => load_lambda_cache(data, &sig, &rt, &delta, &blocks[z], par, ws)?,
                    };
                    set_pos(&mut pos, &cz.cols);
                    // Diagonal bucket.
                    match &colored_buckets {
                        Some(cb) => cd_block_pair_colored(
                            &cb[z * nb + z], &mut cz, None, &pos, &model.lambda, &mut delta,
                            opts.lam_l, &cd_par,
                        ),
                        None => cd_block_pair(
                            &buckets[z * nb + z], &mut cz, None, &pos, &model.lambda,
                            &mut delta, opts.lam_l,
                        ),
                    }
                    for r in (z + 1)..nb {
                        let bucket = &buckets[z * nb + r];
                        if bucket.is_empty() {
                            continue; // clustering win: no cache miss
                        }
                        // Only columns of C_r actually touched (B_zr).
                        let mut bcols: Vec<usize> = bucket
                            .iter()
                            .flat_map(|a| [a.i, a.j])
                            .filter(|&c| block_of[c] == r)
                            .collect();
                        bcols.sort_unstable();
                        bcols.dedup();
                        let mut cr =
                            load_lambda_cache(data, &sig, &rt, &delta, &bcols, par, ws)?;
                        set_pos(&mut pos, &cr.cols);
                        match &colored_buckets {
                            Some(cb) => cd_block_pair_colored(
                                &cb[z * nb + r], &mut cz, Some(&mut cr), &pos,
                                &model.lambda, &mut delta, opts.lam_l, &cd_par,
                            ),
                            None => cd_block_pair(
                                bucket, &mut cz, Some(&mut cr), &pos, &model.lambda,
                                &mut delta, opts.lam_l,
                            ),
                        }
                        clear_pos(&mut pos, &cr.cols);
                    }
                    clear_pos(&mut pos, &cz.cols);
                }
            }
            Ok(())
        })?;

        // ---- Armijo line search on Λ ----
        let tr_gd: f64 = active
            .iter()
            .map(|a| {
                let d = delta.get(a.i, a.j);
                if a.i == a.j {
                    a.grad * d
                } else {
                    2.0 * a.grad * d
                }
            })
            .sum();
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &delta);
        let delta_armijo = tr_gd + opts.lam_l * (lpd.l1_norm() - model.lambda.l1_norm());
        if delta_armijo < -1e-14 {
            let res = prof.time("linesearch", || {
                lambda_line_search(
                    &obj,
                    &model.lambda,
                    &delta,
                    &rt,
                    f,
                    &parts,
                    delta_armijo,
                    model.theta.l1_norm(),
                    engine,
                    &ls_opts,
                )
            })?;
            model.lambda.add_scaled(res.alpha, &delta);
            model.lambda.prune(0.0);
            factor = res.factor;
            parts = res.parts;
            // (f is recomputed after the Θ phase below.)
        }

        // ================= Θ phase =================
        // New CG / oracle on the updated Λ (the line-search factor matches).
        let cg = CgSolver::new(model.lambda.to_csr(), CG_TOL, 20 * q.max(16));
        let sig = pick_sigma(&factor, &cg, opts);
        let theta_reclustered = prof.time("cd:theta", || -> Result<bool, SolveError> {
            let mut caches = ctx.cluster_caches();
            theta_block_sweep(
                data,
                &sig,
                &mut model,
                &theta_active,
                par,
                &cd_par,
                opts,
                ws,
                &mut caches.theta,
                ctx.tiles(),
            )
        })?;
        if theta_reclustered {
            trace.reclusterings += 1;
        }
        model.theta.prune(0.0);
        data.xtheta_t_into(&model.theta, &mut rt);
        parts.tr_sxy_theta = obj.tr_sxy_sparse(&model.theta);
        parts.tr_quad = prof.time("trace_quad", || factor.trace_quad(&rt));
        f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    // Tile-cache observability: under StatMode::Tiled every statistic read
    // above went through the context's tile store — snapshot its counters so
    // the trace shows how many tiles the active buckets actually touched.
    if let Some(tiles) = ctx.tiles() {
        let st = tiles.stats();
        trace.tile_hits = st.hits;
        trace.tile_misses = st.misses;
        trace.tile_evictions = st.evictions;
        trace.tile_spills = st.spills;
        trace.tiles_computed = st.computes;
        trace.total_tiles = tiles.total_tiles();
    }
    Ok(SolveResult { model, trace })
}

// ---------------------------------------------------------------- Λ helpers

/// Cache-sizing policy: the number of Λ blocks k such that 2·(q/k) cached
/// columns (3 q-vectors each) fit in the budget (paper §4.1).
fn lambda_block_count(q: usize, _n: usize, opts: &SolveOptions) -> usize {
    let budget = opts.budget.available().max(1);
    let col_bytes = 3 * q * 8 + 64;
    // 2·(q/k)·col_bytes ≤ budget/2  (half the budget for the Λ cache).
    let max_cols = (budget / 2 / col_bytes).max(2);
    q.div_ceil((max_cols / 2).max(1)).max(1)
}

/// Screen block width: σ/ψ/u triples plus the S_yy and Yᵀ panels per screen
/// column, under the budget.
fn lambda_screen_block(q: usize, n: usize, opts: &SolveOptions) -> usize {
    let budget = opts.budget.available().max(1);
    let col_bytes = (4 * q + n) * 8 + 64;
    ((budget / 2) / col_bytes).clamp(1, q)
}

/// Compute σ, ψ, u columns for `cols` (parallel over columns). The three
/// m×q column matrices are arena checkouts — budget-tracked while cached.
fn load_lambda_cache<'w>(
    data: &Dataset,
    sig: &SigmaOracle,
    rt: &Mat,
    delta: &SpRowMat,
    cols: &[usize],
    par: &Parallelism,
    ws: &'w Workspace,
) -> Result<LambdaCache<'w>, SolveError> {
    let q = sig.n();
    let n = data.n();
    let m = cols.len();
    let mut sigma = ws.mat(m, q)?;
    // σ_t columns.
    par.parallel_chunks_mut(sigma.data_mut(), q, |c, row| {
        sig.unit_column(cols[c], row);
    });
    // ψ_t = (1/n)·Λ⁻¹ R̃ᵀ(R̃σ_t).
    let mut psi = ws.mat(m, q)?;
    {
        let sigma_ref = &*sigma;
        par.parallel_chunks_mut(psi.data_mut(), q, |c, row| {
            let sigcol = sigma_ref.row(c);
            // m2 = R̃σ_t = Σ_j σ[j]·rt.row(j)  (n-vector).
            let mut m2 = vec![0.0; n];
            for (j, &s) in sigcol.iter().enumerate() {
                if s != 0.0 {
                    axpy(s, rt.row(j), &mut m2);
                }
            }
            // m4[j] = dot(rt.row(j), m2) / n.
            let mut m4 = vec![0.0; q];
            let inv_n = 1.0 / n as f64;
            for j in 0..q {
                m4[j] = dot(rt.row(j), &m2) * inv_n;
            }
            if matches!(sig, SigmaOracle::Cg(_)) {
                row.iter_mut().for_each(|x| *x = 0.0);
            }
            sig.solve_into(&m4, row);
        });
    }
    // u_t = Δ σ_t (sparse × dense-column; Δ is symmetric row storage).
    let mut u = ws.mat(m, q)?;
    for c in 0..m {
        let sig = sigma.row(c);
        let urow = u.row_mut(c);
        for i in 0..q {
            let drow = delta.row(i);
            if !drow.is_empty() {
                let mut s = 0.0;
                for &(j, v) in drow {
                    s += v * sig[j];
                }
                urow[i] = s;
            }
        }
    }
    Ok(LambdaCache {
        cols: cols.to_vec(),
        sigma,
        psi,
        u,
    })
}

fn set_pos(pos: &mut [usize], cols: &[usize]) {
    for (c, &t) in cols.iter().enumerate() {
        pos[t] = c;
    }
}

fn clear_pos(pos: &mut [usize], cols: &[usize]) {
    for &t in cols {
        pos[t] = usize::MAX;
    }
}

/// CD updates for all active pairs in one (C_z, C_r) bucket. `cr = None`
/// means the diagonal bucket (both endpoints in `cz`).
fn cd_block_pair(
    bucket: &[ActivePair],
    cz: &mut LambdaCache<'_>,
    mut cr: Option<&mut LambdaCache<'_>>,
    pos: &[usize],
    lambda: &SpRowMat,
    delta: &mut SpRowMat,
    lam_l: f64,
) {
    for a in bucket {
        let (i, j) = (a.i, a.j);
        let mu = {
            // Locate each endpoint's cached column (in cz or cr).
            let (ci, i_in_z) = match locate(cz, cr.as_deref(), pos, i) {
                Some(x) => x,
                None => continue,
            };
            let (cj, j_in_z) = match locate(cz, cr.as_deref(), pos, j) {
                Some(x) => x,
                None => continue,
            };
            let cache_i: &LambdaCache = if i_in_z { &*cz } else { cr.as_deref().unwrap() };
            let cache_j: &LambdaCache = if j_in_z { &*cz } else { cr.as_deref().unwrap() };
            let sig_i = cache_i.sigma.row(ci);
            let sig_j = cache_j.sigma.row(cj);
            let psi_i = cache_i.psi.row(ci);
            let psi_j = cache_j.psi.row(cj);
            let u_i = cache_i.u.row(ci);
            let u_j = cache_j.u.row(cj);
            let (s_ij, s_ii, s_jj) = (sig_j[i], sig_i[i], sig_j[j]);
            let (p_ij, p_ii, p_jj) = (psi_j[i], psi_i[i], psi_j[j]);
            if i == j {
                let aa = s_ii * s_ii + 2.0 * s_ii * p_ii;
                let b = a.grad + dot(sig_i, u_i) + 2.0 * dot(psi_i, u_i);
                let c = lambda.get(i, i) + delta.get(i, i);
                cd_minimizer(aa, b, c, lam_l)
            } else {
                let aa =
                    s_ij * s_ij + s_ii * s_jj + s_ii * p_jj + s_jj * p_ii + 2.0 * s_ij * p_ij;
                let b = a.grad + dot(sig_i, u_j) + dot(psi_i, u_j) + dot(psi_j, u_i);
                let c = lambda.get(i, j) + delta.get(i, j);
                cd_minimizer(aa, b, c, lam_l)
            }
        };
        if mu == 0.0 {
            continue;
        }
        delta.add_sym(i, j, mu);
        // Maintain u_t for every cached column t: u_t[i] += μσ_t[j],
        // u_t[j] += μσ_t[i].
        maintain_u(cz, i, j, mu);
        if let Some(ref mut crr) = cr {
            maintain_u(crr, i, j, mu);
        }
    }
}

/// Color one bucket's pairs into index-disjoint classes for the parallel
/// panel sweep (ephemeral — buckets are rebuilt every outer iteration, so
/// unlike the dense solvers' context-cached colorings these are computed on
/// the fly; a bucket's pairs are few by construction).
fn color_bucket(bucket: &[ActivePair], q: usize) -> Vec<Vec<ActivePair>> {
    if bucket.is_empty() {
        return Vec::new();
    }
    let pairs: Vec<(usize, usize)> = bucket.iter().map(|a| (a.i, a.j)).collect();
    let colors = greedy_color(&pairs, ConflictSpace::Symmetric(q));
    let nc = colors.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
    let mut classes: Vec<Vec<ActivePair>> = vec![Vec::new(); nc];
    for (a, &c) in bucket.iter().zip(&colors) {
        classes[c as usize].push(*a);
    }
    classes
}

/// Raw phase view of one [`LambdaCache`]: read-only σ/ψ panels plus the
/// shared-mutable `u` panel the team's apply phase updates row-disjointly.
struct CacheRawView<'a> {
    cols: &'a [usize],
    sigma: &'a [f64],
    psi: &'a [f64],
    u: SharedSlice,
    rows: usize,
}

fn raw_view<'a>(c: &'a mut LambdaCache<'_>) -> CacheRawView<'a> {
    let rows = c.cols.len();
    CacheRawView {
        cols: &c.cols,
        sigma: c.sigma.data(),
        psi: c.psi.data(),
        u: SharedSlice::new(c.u.data_mut()),
        rows,
    }
}

fn locate_view(
    zv: &CacheRawView<'_>,
    rv: Option<&CacheRawView<'_>>,
    pos: &[usize],
    t: usize,
) -> Option<(usize, bool)> {
    let c = pos[t];
    if c == usize::MAX {
        return None;
    }
    if c < zv.rows && zv.cols[c] == t {
        return Some((c, true));
    }
    if let Some(rv) = rv {
        if c < rv.rows && rv.cols[c] == t {
            return Some((c, false));
        }
    }
    None
}

/// One pair's step from the frozen phase-1 state (the blocked mirror of
/// `cd_common::lambda_coord_mu`, reading cached σ/ψ/u columns).
#[allow(clippy::too_many_arguments)]
fn colored_pair_mu(
    a: &ActivePair,
    zv: &CacheRawView<'_>,
    rv: Option<&CacheRawView<'_>>,
    pos: &[usize],
    lambda: &SpRowMat,
    delta: &SpRowMat,
    lam_l: f64,
    q: usize,
) -> f64 {
    let (i, j) = (a.i, a.j);
    let (ci, i_in_z) = match locate_view(zv, rv, pos, i) {
        Some(x) => x,
        None => return 0.0,
    };
    let (cj, j_in_z) = match locate_view(zv, rv, pos, j) {
        Some(x) => x,
        None => return 0.0,
    };
    let vi = if i_in_z { zv } else { rv.expect("located in cr") };
    let vj = if j_in_z { zv } else { rv.expect("located in cr") };
    let sig_i = &vi.sigma[ci * q..(ci + 1) * q];
    let sig_j = &vj.sigma[cj * q..(cj + 1) * q];
    let psi_i = &vi.psi[ci * q..(ci + 1) * q];
    let psi_j = &vj.psi[cj * q..(cj + 1) * q];
    // SAFETY: phase-1 read; u is not written until after the barrier.
    let u_i = unsafe { vi.u.slice(ci * q, q) };
    let u_j = unsafe { vj.u.slice(cj * q, q) };
    let (s_ij, s_ii, s_jj) = (sig_j[i], sig_i[i], sig_j[j]);
    let (p_ij, p_ii, p_jj) = (psi_j[i], psi_i[i], psi_j[j]);
    if i == j {
        let aa = s_ii * s_ii + 2.0 * s_ii * p_ii;
        let b = a.grad + dot(sig_i, u_i) + 2.0 * dot(psi_i, u_i);
        let c = lambda.get(i, i) + delta.get(i, i);
        cd_minimizer(aa, b, c, lam_l)
    } else {
        let aa = s_ij * s_ij + s_ii * s_jj + s_ii * p_jj + s_jj * p_ii + 2.0 * s_ij * p_ij;
        let b = a.grad + dot(sig_i, u_j) + dot(psi_i, u_j) + dot(psi_j, u_i);
        let c = lambda.get(i, j) + delta.get(i, j);
        cd_minimizer(aa, b, c, lam_l)
    }
}

/// Colored parallel counterpart of [`cd_block_pair`]: Gauss–Seidel across
/// the bucket's color classes, two team phases per class (frozen-state
/// steps, then row-disjoint u maintenance + thread-0 Δ application) — the
/// same scheme as `cd_common`'s colored passes, bitwise-deterministic in
/// the thread count.
#[allow(clippy::too_many_arguments)]
fn cd_block_pair_colored(
    classes: &[Vec<ActivePair>],
    cz: &mut LambdaCache<'_>,
    cr: Option<&mut LambdaCache<'_>>,
    pos: &[usize],
    lambda: &SpRowMat,
    delta: &mut SpRowMat,
    lam_l: f64,
    par: &Parallelism,
) {
    let maxc = classes.iter().map(|c| c.len()).max().unwrap_or(0);
    if maxc == 0 {
        return;
    }
    let q = cz.sigma.cols();
    // Buckets are often tiny (the clustering exists to make off-diagonal
    // buckets rare and small): below this many total O(q) steps a team
    // spawn costs more than it buys, so run the identical colored
    // algorithm on an inline team of one — numerics are thread-count
    // invariant, so this gate cannot change results, only spawn overhead.
    const MIN_PAR_STEPS: usize = 64;
    let total_steps: usize = classes.iter().map(|c| c.len()).sum();
    let inline = Parallelism::new(1);
    let par = if total_steps < MIN_PAR_STEPS { &inline } else { par };
    let zv = raw_view(cz);
    let rv = cr.map(|c| raw_view(c));
    let rv_ref = rv.as_ref();
    let mut mu_buf = vec![0.0f64; maxc];
    let mu_shared = SharedSlice::new(&mut mu_buf);
    let delta_shared = SharedMut::new(delta);
    par.team(|tid, team| {
        let nt = team.threads();
        let mut upd: Vec<(usize, usize, f64)> = Vec::new();
        for class in classes {
            let m = class.len();
            {
                // Phase 1 — SAFETY: delta/u are read-only until the barrier.
                let delta_ro = unsafe { delta_shared.get_ref() };
                for k in (tid..m).step_by(nt) {
                    let mu = colored_pair_mu(
                        &class[k], &zv, rv_ref, pos, lambda, delta_ro, lam_l, q,
                    );
                    unsafe { mu_shared.write(k, mu) };
                }
            }
            team.sync();
            upd.clear();
            {
                let mu_ro = unsafe { mu_shared.slice(0, m) };
                for (k, a) in class.iter().enumerate() {
                    if mu_ro[k] != 0.0 {
                        upd.push((a.i, a.j, mu_ro[k]));
                    }
                }
            }
            if !upd.is_empty() {
                if tid == 0 {
                    // SAFETY: only thread 0 touches delta during phase 2.
                    let dm = unsafe { delta_shared.get_mut() };
                    for &(i, j, mu) in &upd {
                        dm.add_sym(i, j, mu);
                    }
                }
                let total = zv.rows + rv_ref.map_or(0, |v| v.rows);
                for c in (tid..total).step_by(nt) {
                    let (view, cc) = if c < zv.rows {
                        (&zv, c)
                    } else {
                        (rv_ref.expect("c indexes cr rows"), c - zv.rows)
                    };
                    // SAFETY: row cc of this cache is written by one thread.
                    let urow = unsafe { view.u.slice_mut(cc * q, q) };
                    let srow = &view.sigma[cc * q..(cc + 1) * q];
                    for &(i, j, mu) in &upd {
                        if i == j {
                            urow[i] += mu * srow[i];
                        } else {
                            urow[i] += mu * srow[j];
                            urow[j] += mu * srow[i];
                        }
                    }
                }
            }
            team.sync();
        }
    });
}

fn locate(
    cz: &LambdaCache<'_>,
    cr: Option<&LambdaCache<'_>>,
    pos: &[usize],
    t: usize,
) -> Option<(usize, bool)> {
    let c = pos[t];
    if c == usize::MAX {
        return None;
    }
    if c < cz.cols.len() && cz.cols[c] == t {
        return Some((c, true));
    }
    if let Some(cr) = cr {
        if c < cr.cols.len() && cr.cols[c] == t {
            return Some((c, false));
        }
    }
    None
}

fn maintain_u(cache: &mut LambdaCache<'_>, i: usize, j: usize, mu: f64) {
    let m = cache.cols.len();
    let q = cache.sigma.cols();
    let sd = cache.sigma.data();
    let ud = cache.u.data_mut();
    if i == j {
        for c in 0..m {
            ud[c * q + i] += mu * sd[c * q + i];
        }
    } else {
        for c in 0..m {
            let s_j = sd[c * q + j];
            let s_i = sd[c * q + i];
            ud[c * q + i] += mu * s_j;
            ud[c * q + j] += mu * s_i;
        }
    }
}

// ---------------------------------------------------------------- Θ helpers

/// Θ screen: blockwise gradient ∇_Θ = 2S_xy + 2Γ with
/// Γ_blk = Xᵀ(X·ΘΣ_blk)/n via two GEMMs. Returns per-row active column
/// lists with gradient values, plus the subgradient statistic.
type ThetaActive = Vec<(usize, Vec<(usize, f64)>)>;

/// `theta_allowed` (from `SolveOptions::screen`) restricts the scan to each
/// row's allowed columns — the subgradient statistic and active lists then
/// cover exactly the allowed set, mirroring the dense solvers' restricted
/// screens.
///
/// `tiles` (StatMode::Tiled): a *restricted* scan reads its `S_xy` values
/// through the tile cache instead of building the full p×b panel, so the
/// screen only computes the tiles its allowed coordinates live in — the
/// tiled screening win. An unrestricted scan must visit every (i, j) anyway,
/// where the blocked `gemm_nt` panel is strictly cheaper than p·q cache
/// probes, so it keeps the panel path in either mode.
#[allow(clippy::too_many_arguments)]
fn theta_screen(
    data: &Dataset,
    sig: &SigmaOracle,
    model: &CggmModel,
    engine: &dyn GemmEngine,
    par: &Parallelism,
    opts: &SolveOptions,
    ws: &Workspace,
    theta_allowed: Option<&[Vec<usize>]>,
    tiles: Option<&TileStore>,
) -> Result<(ThetaActive, f64), SolveError> {
    let (p, q, n) = (data.p(), data.q(), data.n());
    let bsz = theta_screen_block(p, q, n, opts);
    // Under a restriction, column blocks with no allowed coordinate skip
    // their σ solves and Γ/S_xy GEMMs entirely — the Θ-side screening win
    // (mirrors the Λ screen's column filtering).
    let allowed_in_block: Option<Vec<bool>> = theta_allowed.map(|by_row| {
        let mut any = vec![false; q.div_ceil(bsz)];
        for lst in by_row {
            for &j in lst {
                any[j / bsz] = true;
            }
        }
        any
    });
    // active[i] = list of (j, grad) per row i (built incrementally).
    let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); p];
    let mut subgrad = 0.0;
    let mut t0 = 0;
    while t0 < q {
        let b = bsz.min(q - t0);
        if let Some(any) = &allowed_in_block {
            if !any[t0 / bsz] {
                t0 += b;
                continue;
            }
        }
        let cols: Vec<usize> = (t0..t0 + b).collect();
        // σ columns for this block.
        let mut sigma = ws.mat(b, q)?;
        par.parallel_chunks_mut(sigma.data_mut(), q, |c, row| {
            sig.unit_column(cols[c], row);
        });
        // M = ΘΣ_blk (sparse rows); T = X·M (n×b).
        let mut t_mat = ws.mat(n, b)?;
        for i in 0..p {
            let row = model.theta.row(i);
            if row.is_empty() {
                continue;
            }
            // m_i[c] = Θ_i·σ_c
            let mut mi = vec![0.0; b];
            for (c, m) in mi.iter_mut().enumerate() {
                let sig = sigma.row(c);
                let mut s = 0.0;
                for &(jj, v) in row {
                    s += v * sig[jj];
                }
                *m = s;
            }
            data.with_x_row(i, |xi| {
                for k in 0..n {
                    axpy(xi[k], &mi, t_mat.row_mut(k));
                }
            });
        }
        // Γ_blk = Xᵀ·T / n  (p×b): gemm(xt (p×n), T (n×b)).
        let mut gamma = ws.mat(p, b)?;
        data.gemm_x(engine, data.inv_n(), &t_mat, 0.0, &mut gamma);
        // S_xy block (p×b) — skipped entirely when a restricted tiled scan
        // will read its few entries through the tile cache instead.
        let tiled_scan = tiles.filter(|_| theta_allowed.is_some());
        let sxyb = match tiled_scan {
            Some(_) => None,
            None => {
                let mut ytb = ws.mat(b, n)?;
                data.y_rows_into(&cols, &mut ytb);
                let mut sxyb = ws.mat(p, b)?;
                data.gemm_nt_x(engine, data.inv_n(), &ytb, 0.0, &mut sxyb);
                Some(sxyb)
            }
        };
        // Screen (restricted to each row's allowed columns when screening).
        for i in 0..p {
            let grow = gamma.row(i);
            let srow = sxyb.as_deref().map(|m| m.row(i));
            let mut scan = |c: usize| {
                let j = cols[c];
                let sxy_ij = match (srow, tiled_scan) {
                    (Some(row), _) => row[c],
                    (None, Some(ts)) => ts.sxy_entry(i, j),
                    (None, None) => unreachable!("panel built unless tiled"),
                };
                let g = 2.0 * sxy_ij + 2.0 * grow[c];
                let x = model.theta.get(i, j);
                subgrad += min_norm_subgrad(g, x, opts.lam_t).abs();
                if x != 0.0 || g.abs() > opts.lam_t {
                    per_row[i].push((j, g));
                }
            };
            match theta_allowed {
                Some(by_row) => {
                    let lst = &by_row[i];
                    let start = lst.partition_point(|&j| j < t0);
                    for &j in &lst[start..] {
                        if j >= t0 + b {
                            break;
                        }
                        scan(j - t0);
                    }
                }
                None => (0..b).for_each(scan),
            }
        }
        t0 += b;
    }
    let active: ThetaActive = per_row
        .into_iter()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .collect();
    Ok((active, subgrad))
}

fn theta_screen_block(p: usize, q: usize, n: usize, opts: &SolveOptions) -> usize {
    let budget = opts.budget.available().max(1);
    // Per block column: q (σ) + 2p (Γ, S_xy) + 2n (T panel, Yᵀ rows) doubles.
    let col_bytes = (q + 2 * p + 2 * n) * 8 + 64;
    ((budget / 2) / col_bytes).clamp(1, q)
}

/// One row's outcome from the parallel Θ row phase: updates for Θ's row
/// `i` and the accumulated V-column delta (`dv[c]` = this row's total
/// change to `vt[(c, si)]`), applied after the phase.
struct RowOutcome {
    i: usize,
    si: usize,
    upds: Vec<(usize, f64)>,
    dv: Vec<f64>,
}

/// Θ block CD sweep (Alg. 2 lower half): partition output columns, cache
/// Σ_{C_r} and V rows, update row blocks (i, C_r) with one S_xx row at a
/// time restricted to the support rows. The column partition persists in
/// `theta_cache` across sweeps and λ-path points; returns whether it was
/// rebuilt this call.
///
/// With `cd_par.threads > 1` the row blocks run data-parallel: rows write
/// disjoint V columns, and each row carries its own column delta (`dv`) so
/// its within-row updates stay exact Gauss–Seidel against the frozen
/// cross-row state (Jacobi across rows, like the colored passes). The
/// expensive per-row `S_xx` row reconstructions — the §4.2 cache-miss cost
/// — parallelize with the rows.
///
/// `tiles` (StatMode::Tiled) routes every `S_xx`/`S_xy` read through the
/// context's tile cache: a row's restricted `S_xx` slice resolves only the
/// tiles the support columns live in, and tiles computed for one row are
/// reused by every later row of the same block rows — turning the §4.2
/// per-row O(n·p̃) recompute into amortized tile builds. The store is `Sync`,
/// so the parallel row classes read it from worker threads.
#[allow(clippy::too_many_arguments)]
fn theta_block_sweep(
    data: &Dataset,
    sig: &SigmaOracle,
    model: &mut CggmModel,
    active: &ThetaActive,
    par: &Parallelism,
    cd_par: &Parallelism,
    opts: &SolveOptions,
    ws: &Workspace,
    theta_cache: &mut PersistentPartition,
    tiles: Option<&TileStore>,
) -> Result<bool, SolveError> {
    let q = data.q();
    if active.is_empty() {
        return Ok(false);
    }
    // Support rows: non-empty Θ rows ∪ active rows.
    let mut support: Vec<usize> = model.theta.nonempty_row_indices();
    support.extend(active.iter().map(|(i, _)| *i));
    support.sort_unstable();
    support.dedup();
    let ns = support.len();
    let mut support_pos = vec![usize::MAX; data.p()];
    for (s, &i) in support.iter().enumerate() {
        support_pos[i] = s;
    }

    // Partition columns: cluster the ΘᵀΘ co-occurrence graph of the active
    // set, persisted in the context and rebuilt only on churn. The (row,
    // col) active pairs are the signature: the co-occurrence graph is a pure
    // function of them, so an unchanged signature means an identical graph.
    let k_t = theta_block_count(q, ns, opts);
    let mut reclustered = false;
    let blocks: Vec<Vec<usize>> = if opts.clustering && k_t > 1 {
        let mut sig_pairs: Vec<(usize, usize)> = active
            .iter()
            .flat_map(|(i, v)| v.iter().map(move |&(j, _)| (*i, j)))
            .collect();
        sig_pairs.sort_unstable();
        sig_pairs.dedup();
        let (blocks, rebuilt) = theta_cache.blocks_cached(
            q,
            k_t,
            &ClusterOptions {
                seed: opts.seed ^ 0x5eed,
                ..Default::default()
            },
            sig_pairs,
            opts.recluster_churn,
            || {
                let rows: Vec<Vec<usize>> = active
                    .iter()
                    .map(|(_, v)| v.iter().map(|(j, _)| *j).collect())
                    .collect();
                Graph::theta_column_graph(&rows, q)
            },
        );
        reclustered = rebuilt;
        blocks
    } else {
        contiguous_blocks(q, k_t)
    };
    let mut block_of = vec![0usize; q];
    for (b, cols) in blocks.iter().enumerate() {
        for &c in cols {
            block_of[c] = b;
        }
    }

    // Per-row active lists bucketed by block.
    // row_actives[b] = Vec<(row i, Vec<(col j, grad)>)> restricted to block b.
    let nb = blocks.len();
    let mut row_actives: Vec<Vec<(usize, Vec<(usize, f64)>)>> = vec![Vec::new(); nb];
    for (i, cols) in active {
        let mut per_block: Vec<Vec<(usize, f64)>> = vec![Vec::new(); nb];
        for &(j, g) in cols {
            per_block[block_of[j]].push((j, g));
        }
        for (b, v) in per_block.into_iter().enumerate() {
            if !v.is_empty() {
                row_actives[b].push((*i, v));
            }
        }
    }

    let mut sxx_row: Vec<f64> = Vec::new();
    for _ in 0..opts.inner_sweeps {
        for (b, cols) in blocks.iter().enumerate() {
            if row_actives[b].is_empty() {
                continue;
            }
            let bsz = cols.len();
            // σ columns of this block.
            let mut sigma = ws.mat(bsz, q)?;
            par.parallel_chunks_mut(sigma.data_mut(), q, |c, row| {
                sig.unit_column(cols[c], row);
            });
            // vt[c][s] = V[support[s]][c] = Θ_{support[s],:}·σ_c.
            let mut vt = ws.mat(bsz, ns)?;
            for (s, &i) in support.iter().enumerate() {
                let row = model.theta.row(i);
                if row.is_empty() {
                    continue;
                }
                for c in 0..bsz {
                    let sig = sigma.row(c);
                    let mut acc = 0.0;
                    for &(jj, v) in row {
                        acc += v * sig[jj];
                    }
                    vt[(c, s)] = acc;
                }
            }
            // Column position lookup within this block.
            let mut col_pos = vec![usize::MAX; q];
            for (c, &j) in cols.iter().enumerate() {
                col_pos[j] = c;
            }
            if cd_par.threads > 1 {
                // Row blocks in parallel. Rows sharing an active column in
                // this block couple at *first order* (2·S_xx[i1,i2]·Σ[jj]),
                // so — exactly like the elementwise colored sweeps — they
                // are separated into classes (greedy group coloring over
                // the block's columns) and the classes run Gauss–Seidel:
                // within one class rows share no column, each computes its
                // S_xx row and sweeps its own columns exactly (own-column
                // delta carried in dv), and the outcomes are applied in
                // row order before the next class sees V. Per-row scratch
                // is thread-local by necessity (the workspace arena is
                // single-owner) and dwarfed by the O(n·p̃) S_xx row
                // reconstruction it sits next to.
                let rows = &row_actives[b];
                let occ: Vec<Vec<usize>> = rows
                    .iter()
                    .map(|(_, jl)| jl.iter().map(|&(j, _)| col_pos[j]).collect())
                    .collect();
                let colors = crate::graph::coloring::greedy_color_groups(
                    occ.iter().map(|v| v.as_slice()),
                    bsz,
                );
                let nclasses = colors.iter().map(|&c| c + 1).max().unwrap_or(0);
                for class in 0..nclasses {
                    let members: Vec<usize> = (0..rows.len())
                        .filter(|&r| colors[r] == class)
                        .collect();
                    // Tiny classes run the identical code on one thread —
                    // a spawn would cost more than the rows it covers (the
                    // gate is size-only, so results stay thread-count
                    // invariant).
                    let inline = Parallelism::new(1);
                    let class_par = if members.len() < 4 { &inline } else { cd_par };
                    let mut slots: Vec<Option<RowOutcome>> = Vec::new();
                    slots.resize_with(members.len(), || None);
                    {
                        let sigma_d = sigma.data();
                        let vt_d = vt.data();
                        let theta_ro = &model.theta;
                        let support_ref: &[usize] = &support;
                        let support_pos_ref: &[usize] = &support_pos;
                        let col_pos_ref: &[usize] = &col_pos;
                        let members_ref: &[usize] = &members;
                        class_par.parallel_chunks_mut(&mut slots, 1, |mk, slot| {
                            let (i, jlist) = &rows[members_ref[mk]];
                            let i = *i;
                            let mut row_buf: Vec<f64> = Vec::new();
                            let sxx_ii = match tiles {
                                Some(ts) => {
                                    ts.sxx_row_restricted(i, support_ref, &mut row_buf);
                                    ts.sxx_entry(i, i)
                                }
                                None => {
                                    data.sxx_row_restricted(i, support_ref, &mut row_buf);
                                    data.sxx(i, i)
                                }
                            };
                            let si = support_pos_ref[i];
                            debug_assert!(si != usize::MAX);
                            let mut dv = vec![0.0; bsz];
                            let mut upds: Vec<(usize, f64)> = Vec::new();
                            for &(j, _g) in jlist {
                                let c = col_pos_ref[j];
                                debug_assert!(c != usize::MAX);
                                let sig_c = &sigma_d[c * q..(c + 1) * q];
                                let a = 2.0 * sxx_ii * sig_c[j];
                                if a <= 0.0 {
                                    continue;
                                }
                                // Frozen class-entry V plus this row's own
                                // accumulated column delta — exact
                                // within-row Gauss–Seidel.
                                let vt_c = &vt_d[c * ns..(c + 1) * ns];
                                let sxy_ij = match tiles {
                                    Some(ts) => ts.sxy_entry(i, j),
                                    None => data.sxy(i, j),
                                };
                                let b_lin = 2.0 * sxy_ij
                                    + 2.0 * (dot(&row_buf, vt_c) + row_buf[si] * dv[c]);
                                let cc = theta_ro.get(i, j);
                                let mu = cd_minimizer(a, b_lin, cc, opts.lam_t);
                                if mu != 0.0 {
                                    upds.push((j, mu));
                                    for (cp, d) in dv.iter_mut().enumerate() {
                                        *d += mu * sigma_d[cp * q + j];
                                    }
                                }
                            }
                            slot[0] = Some(RowOutcome { i, si, upds, dv });
                        });
                    }
                    for slot in slots {
                        let out = slot.expect("every row slot is filled");
                        for &(j, mu) in &out.upds {
                            model.theta.add(out.i, j, mu);
                        }
                        for (cp, d) in out.dv.iter().enumerate() {
                            if *d != 0.0 {
                                vt[(cp, out.si)] += *d;
                            }
                        }
                    }
                }
            } else {
                // Row blocks (i, C_b), serial.
                for (i, jlist) in &row_actives[b] {
                    let i = *i;
                    // One S_xx row, restricted to the support (cache miss
                    // cost O(n·p̃), §4.2) — or tile-cache reads under
                    // StatMode::Tiled, which amortize across rows.
                    let sxx_ii = match tiles {
                        Some(ts) => {
                            ts.sxx_row_restricted(i, &support, &mut sxx_row);
                            ts.sxx_entry(i, i)
                        }
                        None => {
                            data.sxx_row_restricted(i, &support, &mut sxx_row);
                            data.sxx(i, i)
                        }
                    };
                    let si = support_pos[i];
                    debug_assert!(si != usize::MAX);
                    for &(j, _g) in jlist {
                        let c = col_pos[j];
                        debug_assert!(c != usize::MAX);
                        let sig_c = sigma.row(c);
                        let a = 2.0 * sxx_ii * sig_c[j];
                        if a <= 0.0 {
                            continue;
                        }
                        let sxy_ij = match tiles {
                            Some(ts) => ts.sxy_entry(i, j),
                            None => data.sxy(i, j),
                        };
                        let b_lin = 2.0 * sxy_ij + 2.0 * dot(&sxx_row, vt.row(c));
                        let cc = model.theta.get(i, j);
                        let mu = cd_minimizer(a, b_lin, cc, opts.lam_t);
                        if mu != 0.0 {
                            model.theta.add(i, j, mu);
                            // V_{i,:}|block += μΣ_{j,:}|block
                            // ⇒ vt[c'][si] += μσ_{c'}[j].
                            for cprime in 0..bsz {
                                let sjc = sigma[(cprime, j)];
                                vt[(cprime, si)] += mu * sjc;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(reclustered)
}

fn theta_block_count(q: usize, support: usize, opts: &SolveOptions) -> usize {
    let budget = opts.budget.available().max(1);
    let col_bytes = (q + support) * 8 + 64;
    let max_cols = ((budget / 2) / col_bytes).max(1);
    q.div_ceil(max_cols).max(1)
}

/// Exact λ_max statistics for the λ-path driver, computed the block-solver
/// way: streamed in budget-tracked column panels (the same `rows_into` +
/// `gemm_nt` layout as the Λ/Θ screens above, kept in one module so the
/// sizing cannot drift from the screens'). Never materializes dense q×q or
/// p×q matrices. Returns (max off-diagonal |S_yy|, max 2·|S_xy|) — the
/// gradient magnitudes at the cold-start iterate (Λ = I, Θ = 0).
pub(crate) fn streamed_lambda_max(
    data: &Dataset,
    engine: &dyn GemmEngine,
    ws: &Workspace,
) -> Result<(f64, f64), SolveError> {
    let (p, q, n) = (data.p(), data.q(), data.n());
    // Per panel column: q (S_yy) + p (S_xy) + n (Yᵀ rows) doubles.
    let col_bytes = (q + p + n) * 8 + 64;
    let bsz = ((ws.budget().available().max(1) / 2) / col_bytes).clamp(1, q);
    let (mut ml, mut mt) = (1e-12f64, 1e-12f64);
    let mut t0 = 0;
    while t0 < q {
        let b = bsz.min(q - t0);
        let cols: Vec<usize> = (t0..t0 + b).collect();
        let mut ytb = ws.mat(b, n)?;
        data.y_rows_into(&cols, &mut ytb);
        // S_yy panel (q×b): off-diagonal max.
        let mut syyb = ws.mat(q, b)?;
        data.gemm_nt_y(engine, data.inv_n(), &ytb, 0.0, &mut syyb);
        for i in 0..q {
            for (c, v) in syyb.row(i).iter().enumerate() {
                if i != t0 + c {
                    ml = ml.max(v.abs());
                }
            }
        }
        // S_xy panel (p×b).
        let mut sxyb = ws.mat(p, b)?;
        data.gemm_nt_x(engine, data.inv_n(), &ytb, 0.0, &mut sxyb);
        for v in sxyb.data() {
            mt = mt.max(2.0 * v.abs());
        }
        t0 += b;
    }
    Ok((ml, mt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;
    use crate::util::membudget::MemBudget;

    fn run(
        prob: &datagen::Problem,
        opts: &SolveOptions,
        eng: &NativeGemm,
    ) -> Result<SolveResult, SolveError> {
        let ctx = SolverContext::new(&prob.data, opts, eng);
        solve(&ctx, opts, None)
    }

    #[test]
    fn converges_on_tiny_chain() {
        let prob = datagen::chain::generate(12, 12, 80, 3);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.15,
            lam_t: 0.15,
            max_iter: 60,
            chol: crate::cggm::CholKind::SparseRcm,
            ..Default::default()
        };
        let res = run(&prob, &opts, &eng).unwrap();
        assert!(res.trace.converged, "bcd did not converge");
        let fs: Vec<f64> = res.trace.records.iter().map(|r| r.f).collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-7, "f increased: {fs:?}");
        }
    }

    #[test]
    fn tight_budget_forces_many_blocks_same_answer() {
        let prob = datagen::chain::generate(10, 10, 60, 9);
        let eng = NativeGemm::new(1);
        let base = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 50,
            chol: crate::cggm::CholKind::SparseRcm,
            ..Default::default()
        };
        let unlimited = run(&prob, &base, &eng).unwrap();
        // A budget that only fits a handful of cached columns.
        let tight = SolveOptions {
            budget: MemBudget::new(64 * 1024),
            ..base
        };
        let constrained = run(&prob, &tight, &eng).unwrap();
        let fu = unlimited.trace.final_f().unwrap();
        let fc = constrained.trace.final_f().unwrap();
        assert!(
            (fu - fc).abs() < 1e-4 * fu.abs().max(1.0),
            "objectives differ: {fu} vs {fc}"
        );
        assert!(constrained.trace.converged);
        // Budget was respected.
        assert!(tight.budget.peak() <= 64 * 1024);
    }
}
