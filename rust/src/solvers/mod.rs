//! The three CGGM solvers:
//!
//! - [`newton_cd`] — the prior state of the art (Wytock & Kolter 2013):
//!   one joint second-order model over (Λ, Θ), coordinate descent on the
//!   joint Lasso subproblem, joint line search. The paper's baseline.
//! - [`alt_newton_cd`] — **Algorithm 1**: alternate a generalized Newton
//!   step in Λ with *direct* coordinate descent on the quadratic Θ
//!   subproblem. No Γ, no cross-Hessian, no Θ line search.
//! - [`alt_newton_bcd`] — **Algorithm 2**: Algorithm 1 restructured into
//!   block coordinate descent with clustered blocks, CG-computed Σ columns,
//!   and a memory budget — runs at sizes where the others cannot allocate
//!   their dense q×q / p×q work matrices.

pub mod alt_newton_bcd;
pub mod alt_newton_cd;
pub mod cd_common;
pub mod newton_cd;
pub mod prox_grad;

use crate::cggm::factor::CholKind;
use crate::cggm::{CggmModel, Dataset};
use crate::gemm::GemmEngine;
use crate::metrics::SolveTrace;
use crate::util::membudget::MemBudget;
use crate::util::threadpool::Parallelism;

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Joint Newton coordinate descent (baseline, Wytock & Kolter).
    NewtonCd,
    /// Alternating Newton coordinate descent (Algorithm 1).
    AltNewtonCd,
    /// Alternating Newton block coordinate descent (Algorithm 2).
    AltNewtonBcd,
    /// Accelerated proximal gradient (FISTA) — the first-order prior-art
    /// baseline (paper refs [8, 11]).
    ProxGrad,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s {
            "newton" | "newton-cd" | "ncd" => Some(SolverKind::NewtonCd),
            "alt" | "alt-newton-cd" | "ancd" => Some(SolverKind::AltNewtonCd),
            "bcd" | "alt-newton-bcd" | "anbcd" => Some(SolverKind::AltNewtonBcd),
            "prox" | "fista" | "prox-grad" => Some(SolverKind::ProxGrad),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::NewtonCd => "newton_cd",
            SolverKind::AltNewtonCd => "alt_newton_cd",
            SolverKind::AltNewtonBcd => "alt_newton_bcd",
            SolverKind::ProxGrad => "prox_grad",
        }
    }

    pub fn all() -> [SolverKind; 3] {
        [
            SolverKind::NewtonCd,
            SolverKind::AltNewtonCd,
            SolverKind::AltNewtonBcd,
        ]
    }
}

/// Solver configuration shared by all three methods.
#[derive(Clone)]
pub struct SolveOptions {
    /// λ_Λ.
    pub lam_l: f64,
    /// λ_Θ.
    pub lam_t: f64,
    /// Outer (Newton) iteration cap.
    pub max_iter: usize,
    /// Stopping rule: ‖grad^S f‖₁ < tol·(‖Λ‖₁ + ‖Θ‖₁)  (paper: 0.01).
    pub tol: f64,
    /// CD passes over the active set per subproblem (paper: 1).
    pub inner_sweeps: usize,
    /// Worker threads (paper §Parallelization).
    pub threads: usize,
    /// Λ factorization strategy.
    pub chol: CholKind,
    /// Memory budget for the block solver's caches.
    pub budget: MemBudget,
    /// Use graph clustering for block selection (ablation switch; `false`
    /// falls back to contiguous blocks).
    pub clustering: bool,
    /// Wall-clock cap in seconds (0 = none) — the paper terminated runs at
    /// 60 h; scaled experiments use minutes.
    pub time_limit: f64,
    /// Record objective value every iteration (costs one factorization's
    /// worth of work per iteration; used for the convergence figures).
    pub trace_f: bool,
    /// Seed for clustering tie-breaking.
    pub seed: u64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            lam_l: 0.5,
            lam_t: 0.5,
            max_iter: 100,
            tol: 0.01,
            inner_sweeps: 1,
            threads: 1,
            chol: CholKind::Auto,
            budget: MemBudget::unlimited(),
            clustering: true,
            time_limit: 0.0,
            trace_f: true,
            seed: 7,
        }
    }
}

impl SolveOptions {
    pub fn parallelism(&self) -> Parallelism {
        Parallelism::new(self.threads)
    }

    /// True when the wall-clock cap is exceeded.
    pub fn out_of_time(&self, elapsed: f64) -> bool {
        self.time_limit > 0.0 && elapsed > self.time_limit
    }
}

/// Solve outcome.
pub struct SolveResult {
    pub model: CggmModel,
    pub trace: SolveTrace,
}

#[derive(Debug, thiserror::Error)]
pub enum SolveError {
    #[error("line search failed: {0}")]
    LineSearch(#[from] crate::cggm::linesearch::LineSearchError),
    #[error("Λ factorization failed: {0}")]
    Factor(#[from] crate::cggm::factor::FactorError),
    #[error("memory budget cannot hold the minimum working set: {0}")]
    Budget(#[from] crate::util::membudget::BudgetExceeded),
}

/// Dispatch entry point.
pub fn solve(
    kind: SolverKind,
    data: &Dataset,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
) -> Result<SolveResult, SolveError> {
    match kind {
        SolverKind::NewtonCd => newton_cd::solve(data, opts, engine),
        SolverKind::AltNewtonCd => alt_newton_cd::solve(data, opts, engine),
        SolverKind::AltNewtonBcd => alt_newton_bcd::solve(data, opts, engine),
        SolverKind::ProxGrad => prox_grad::solve(data, opts, engine),
    }
}

/// Estimated dense working-set bytes of the non-block solvers — used by the
/// `memwall` experiment to reproduce the paper's OOM boundary.
pub fn dense_workingset_bytes(kind: SolverKind, p: usize, q: usize) -> usize {
    let f = std::mem::size_of::<f64>();
    match kind {
        // S_yy, Σ, Ψ, W(=Uᵀ) : q²; S_xx: p²; Vᵀ: pq.
        SolverKind::AltNewtonCd => f * (4 * q * q + p * p + p * q),
        // + Γ and Γᵀ (pq), V'ᵀ (pq).
        SolverKind::NewtonCd => f * (4 * q * q + p * p + 4 * p * q),
        SolverKind::AltNewtonBcd => 0, // governed by the budget instead
        // Dense iterates + Γ: q² ×4 + pq ×3 (x, y, grads) + p² is avoided.
        SolverKind::ProxGrad => f * (4 * q * q + 3 * p * q),
    }
}
