//! The four CGGM solvers:
//!
//! - [`newton_cd`] — the prior state of the art (Wytock & Kolter 2013):
//!   one joint second-order model over (Λ, Θ), coordinate descent on the
//!   joint Lasso subproblem, joint line search. The paper's baseline.
//! - [`alt_newton_cd`] — **Algorithm 1**: alternate a generalized Newton
//!   step in Λ with *direct* coordinate descent on the quadratic Θ
//!   subproblem. No Γ, no cross-Hessian, no Θ line search.
//! - [`alt_newton_bcd`] — **Algorithm 2**: Algorithm 1 restructured into
//!   block coordinate descent with clustered blocks, CG-computed Σ columns,
//!   and a memory budget — runs at sizes where the others cannot allocate
//!   their dense q×q / p×q work matrices.
//! - [`prox_grad`] — accelerated proximal gradient (FISTA), the first-order
//!   prior-art baseline the second-order methods are measured against.
//!
//! All four run on a shared [`SolverContext`]: cached covariance statistics
//! (computed once per dataset, reused across solves — the λ-path driver's
//! speed story), a budget-tracked [`workspace::Workspace`] arena supplying
//! every hot-loop buffer, and the GEMM engine + parallelism handles. The
//! one-shot [`solve`] entry point builds a context internally;
//! [`solve_in_context`] lets callers (warm-started paths, repeated fits)
//! amortize it.

pub mod alt_newton_bcd;
pub mod alt_newton_cd;
pub mod cd_common;
pub mod context;
pub mod newton_cd;
pub mod prox_grad;
pub mod workspace;

pub use context::{SolverContext, StatCarry};
pub use workspace::Workspace;

use crate::cggm::active::ScreenSet;
use crate::cggm::factor::CholKind;
use crate::cggm::{CggmModel, Dataset};
use crate::gemm::GemmEngine;
use crate::metrics::SolveTrace;
use crate::util::membudget::MemBudget;
use crate::util::threadpool::Parallelism;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Cooperative cancellation handle, polled at the same per-iteration /
/// per-λ-point sites as the wall-clock budget. The default ([`CancelToken::none`])
/// carries no flag and costs one branch per poll; [`CancelToken::armed`]
/// shares an atomic flag between the solver and whoever may cancel it (the
/// serve engine's `cancel` op). Cloning shares the flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Option<Arc<AtomicBool>>);

impl CancelToken {
    /// A token that can never fire (the non-serving default).
    pub fn none() -> CancelToken {
        CancelToken(None)
    }

    /// A live token; keep a clone to [`CancelToken::cancel`] later.
    pub fn armed() -> CancelToken {
        CancelToken(Some(Arc::new(AtomicBool::new(false))))
    }

    /// Request cancellation. No-op on an unarmed token.
    pub fn cancel(&self) {
        if let Some(flag) = &self.0 {
            flag.store(true, Ordering::Relaxed);
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.0
            .as_ref()
            .map(|flag| flag.load(Ordering::Relaxed))
            .unwrap_or(false)
    }
}

/// Which solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Joint Newton coordinate descent (baseline, Wytock & Kolter).
    NewtonCd,
    /// Alternating Newton coordinate descent (Algorithm 1).
    AltNewtonCd,
    /// Alternating Newton block coordinate descent (Algorithm 2).
    AltNewtonBcd,
    /// Accelerated proximal gradient (FISTA) — the first-order prior-art
    /// baseline (paper refs [8, 11]).
    ProxGrad,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s {
            "newton" | "newton-cd" | "ncd" => Some(SolverKind::NewtonCd),
            "alt" | "alt-newton-cd" | "ancd" => Some(SolverKind::AltNewtonCd),
            "bcd" | "alt-newton-bcd" | "anbcd" => Some(SolverKind::AltNewtonBcd),
            "prox" | "fista" | "prox-grad" => Some(SolverKind::ProxGrad),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::NewtonCd => "newton_cd",
            SolverKind::AltNewtonCd => "alt_newton_cd",
            SolverKind::AltNewtonBcd => "alt_newton_bcd",
            SolverKind::ProxGrad => "prox_grad",
        }
    }

    /// The paper's three solvers (Table 1 / Figures 1–2). Formerly misnamed
    /// `all()`, which silently omitted [`SolverKind::ProxGrad`].
    pub fn paper_three() -> [SolverKind; 3] {
        [
            SolverKind::NewtonCd,
            SolverKind::AltNewtonCd,
            SolverKind::AltNewtonBcd,
        ]
    }

    /// Whether the λ-path *driver* engages screening — including its
    /// per-point dense gradient evaluations — for this solver. All three
    /// dense-statistic solvers restrict their screens (and CD / prox work)
    /// to the allowed set. The block solver also honors a caller-provided
    /// [`SolveOptions::screen`] at the solver level (its blockwise Λ/Θ
    /// screens and panel sweeps restrict to the allowed coordinates), but
    /// stays off this list: the *driver's* dense gradient evaluations
    /// would materialize the q×q/p×q matrices its memory story exists to
    /// avoid.
    pub fn supports_screen(&self) -> bool {
        matches!(
            self,
            SolverKind::AltNewtonCd | SolverKind::NewtonCd | SolverKind::ProxGrad
        )
    }

    /// Every solver the crate implements, including the first-order baseline.
    pub fn all() -> [SolverKind; 4] {
        [
            SolverKind::NewtonCd,
            SolverKind::AltNewtonCd,
            SolverKind::AltNewtonBcd,
            SolverKind::ProxGrad,
        ]
    }
}

/// How a solver's covariance statistics are materialized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatMode {
    /// Eager dense `S_yy`/`S_xx`/`S_xy`, cached whole in the context — the
    /// historical path, and the only one the dense-row CD solvers
    /// (`newton_cd`, `alt_newton_cd`, whose Θ updates read contiguous
    /// `S_xx` rows) can use.
    Dense,
    /// Demand-driven `tile × tile` Gram blocks behind the context's
    /// [`crate::cggm::tiles::TileStore`]: computed on first touch, LRU-cached
    /// against the budget, spilled to disk under pressure. Honored by
    /// `alt_newton_bcd` and the screening entry paths; solvers that need
    /// dense statistics simply keep the eager path (the mode is a memory/
    /// compute optimization, never a semantic change).
    Tiled(usize),
}

impl StatMode {
    /// Parse a config/CLI mode string; `tile` supplies the block edge for
    /// `"tiled"`.
    pub fn parse(mode: &str, tile: usize) -> Option<StatMode> {
        match mode {
            "dense" | "eager" => Some(StatMode::Dense),
            "tiled" | "tiles" | "lazy" if tile >= 1 => Some(StatMode::Tiled(tile)),
            _ => None,
        }
    }

    pub fn is_tiled(&self) -> bool {
        matches!(self, StatMode::Tiled(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            StatMode::Dense => "dense",
            StatMode::Tiled(_) => "tiled",
        }
    }
}

impl Default for StatMode {
    fn default() -> Self {
        StatMode::Dense
    }
}

/// Solver configuration shared by all four methods.
#[derive(Clone)]
pub struct SolveOptions {
    /// λ_Λ.
    pub lam_l: f64,
    /// λ_Θ.
    pub lam_t: f64,
    /// Outer (Newton) iteration cap.
    pub max_iter: usize,
    /// Stopping rule: ‖grad^S f‖₁ < tol·(‖Λ‖₁ + ‖Θ‖₁)  (paper: 0.01).
    pub tol: f64,
    /// CD passes over the active set per subproblem (paper: 1).
    pub inner_sweeps: usize,
    /// Worker threads (paper §Parallelization) for the column-parallel
    /// work: Σ column solves, GEMM bands, fold-parallel drivers.
    pub threads: usize,
    /// Worker threads for the coordinate-descent sweeps themselves. `> 1`
    /// switches every CD hot loop to the *colored* passes: the active set's
    /// conflict graph ([`crate::graph::coloring`], cached in the
    /// [`SolverContext`] and rebuilt only on active-set churn) partitions
    /// coordinates into index-disjoint classes, processed Gauss–Seidel
    /// across classes and data-parallel within one. `1` (default) keeps the
    /// bit-exact serial sweeps. Kept separate from `threads` because the
    /// two parallelize different grains (long column solves vs O(q) updates)
    /// and tuning them independently matters — see docs/PERF.md.
    pub cd_threads: usize,
    /// Λ factorization strategy.
    pub chol: CholKind,
    /// Memory budget for the block solver's caches.
    pub budget: MemBudget,
    /// Use graph clustering for block selection (ablation switch; `false`
    /// falls back to contiguous blocks).
    pub clustering: bool,
    /// Wall-clock cap in seconds (0 = none) — the paper terminated runs at
    /// 60 h; scaled experiments use minutes.
    pub time_limit: f64,
    /// Record objective value every iteration (costs one factorization's
    /// worth of work per iteration; used for the convergence figures).
    pub trace_f: bool,
    /// Seed for clustering tie-breaking.
    pub seed: u64,
    /// Active-set churn (Jaccard distance vs the partition's build-time set)
    /// above which the block solver recomputes its graph-clustering
    /// partition. The partition is cached in the [`SolverContext`], so along
    /// a λ path (where supports change slowly) adjacent points — and outer
    /// iterations within a point — reuse it instead of re-deriving column
    /// clusterings from scratch. `0.0` reclusters on any change; a negative
    /// value forces reclustering every time (the ablation the persistence
    /// tests compare against); `>= 1.0` never reclusters once built.
    pub recluster_churn: f64,
    /// Restrict screening (and hence all CD work) to this coordinate set —
    /// the λ-path driver's sequential strong rule
    /// ([`crate::cggm::active::ScreenSet`]). `None` screens every
    /// coordinate. Honored by the dense-stat CD solvers (`alt_newton_cd`,
    /// which also skips the dense ∇_Θ GEMM when restricted); solvers that
    /// ignore it simply solve the unrestricted problem, which is always
    /// correct — the restriction is an optimization, never a semantic
    /// change, and the path driver's KKT post-check holds either way.
    pub screen: Option<Arc<ScreenSet>>,
    /// Covariance statistics materialization ([`StatMode`]). `Tiled` routes
    /// the block solver's and the screening paths' statistic reads through
    /// the context's on-demand tile cache.
    pub stat_mode: StatMode,
    /// Cooperative cancellation, polled wherever `time_limit` already is
    /// (each solver's outer loop and the λ-path driver's per-point check).
    /// A fired token surfaces as [`SolveError::Cancelled`]. Defaults to the
    /// unarmed no-op token.
    pub cancel: CancelToken,
    /// Drift-accumulation guard for incremental statistics maintenance
    /// ([`SolverContext::update_stats`]): force a from-scratch rebuild of
    /// every cached statistic after this many sample-*removing* window
    /// updates (each downdate is a subtractive rank-k correction whose
    /// floating-point error compounds; see docs/PERF.md). `0` disables the
    /// guard.
    pub stat_rebuild_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            lam_l: 0.5,
            lam_t: 0.5,
            max_iter: 100,
            tol: 0.01,
            inner_sweeps: 1,
            threads: 1,
            cd_threads: 1,
            chol: CholKind::Auto,
            budget: MemBudget::unlimited(),
            clustering: true,
            time_limit: 0.0,
            trace_f: true,
            seed: 7,
            recluster_churn: 0.2,
            screen: None,
            stat_mode: StatMode::default(),
            cancel: CancelToken::none(),
            stat_rebuild_every: 64,
        }
    }
}

impl SolveOptions {
    pub fn parallelism(&self) -> Parallelism {
        Parallelism::new(self.threads)
    }

    /// Parallelism handle for the colored CD sweeps (`--cd-threads`).
    pub fn cd_parallelism(&self) -> Parallelism {
        Parallelism::new(self.cd_threads)
    }

    /// Whether the colored (conflict-free parallel) CD passes are engaged.
    pub fn colored_cd(&self) -> bool {
        self.cd_threads > 1
    }

    /// True when the wall-clock cap is reached. `>=` so `time_limit` is
    /// honored exactly at the cap (a run timed at precisely the limit stops).
    pub fn out_of_time(&self, elapsed: f64) -> bool {
        self.time_limit > 0.0 && elapsed >= self.time_limit
    }
}

/// Solve outcome.
pub struct SolveResult {
    pub model: CggmModel,
    pub trace: SolveTrace,
}

#[derive(Debug, thiserror::Error)]
pub enum SolveError {
    #[error("line search failed: {0}")]
    LineSearch(crate::cggm::linesearch::LineSearchError),
    #[error("Λ factorization failed: {0}")]
    Factor(crate::cggm::factor::FactorError),
    #[error("memory budget cannot hold the minimum working set: {0}")]
    Budget(#[from] crate::util::membudget::BudgetExceeded),
    #[error("checkpoint io: {0}")]
    Checkpoint(String),
    /// The run's [`CancelToken`] fired; the partial iterate is discarded.
    #[error("job cancelled")]
    Cancelled,
}

// Manual `From` impls so budget failures keep one face: a factorization or
// line-search trial the budget cannot hold surfaces as `SolveError::Budget`
// — the paper's "out of memory" — no matter which layer detected it.
impl From<crate::cggm::factor::FactorError> for SolveError {
    fn from(e: crate::cggm::factor::FactorError) -> SolveError {
        match e {
            crate::cggm::factor::FactorError::Budget(b) => SolveError::Budget(b),
            other => SolveError::Factor(other),
        }
    }
}

impl From<crate::cggm::linesearch::LineSearchError> for SolveError {
    fn from(e: crate::cggm::linesearch::LineSearchError) -> SolveError {
        match e {
            crate::cggm::linesearch::LineSearchError::Budget(b) => SolveError::Budget(b),
            other => SolveError::LineSearch(other),
        }
    }
}

/// One-shot dispatch: builds a fresh [`SolverContext`] for this solve.
pub fn solve(
    kind: SolverKind,
    data: &Dataset,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
) -> Result<SolveResult, SolveError> {
    let ctx = SolverContext::new(data, opts, engine);
    solve_in_context(kind, &ctx, opts, None)
}

/// Dispatch on a shared context. `warm` seeds the iterate (λ-path warm
/// starts); `None` is the paper's cold start (Λ = I, Θ = 0). Cached
/// statistics and workspace buffers persist across calls on the same
/// context.
pub fn solve_in_context(
    kind: SolverKind,
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
) -> Result<SolveResult, SolveError> {
    // Panel-cache counters are cumulative per backing store (shared across
    // solves and clones); snapshot so the trace reports *this solve's* I/O.
    let panel0 = ctx.data().panel_stats().unwrap_or_default();
    let mut res = match kind {
        SolverKind::NewtonCd => newton_cd::solve(ctx, opts, warm),
        SolverKind::AltNewtonCd => alt_newton_cd::solve(ctx, opts, warm),
        SolverKind::AltNewtonBcd => alt_newton_bcd::solve(ctx, opts, warm),
        SolverKind::ProxGrad => prox_grad::solve(ctx, opts, warm),
    }?;
    // Recorded centrally so every solver's trace reports warm-start reuse
    // and incremental statistics maintenance (the serve engine and λ-path
    // observability both read these).
    res.trace.warm_started = warm.is_some();
    res.trace.stat_updates = ctx.stat_updates();
    if let Some(ps) = ctx.data().panel_stats() {
        res.trace.panel_reads = ps.reads.saturating_sub(panel0.reads);
        res.trace.panel_cache_hits = ps.hits.saturating_sub(panel0.hits);
    }
    Ok(res)
}

/// Estimated dense working-set bytes of the non-block solvers — used by the
/// `memwall` experiment to reproduce the paper's OOM boundary. An analytic
/// estimate of the *iterate-and-cache* set only; Cholesky factors (q²·8
/// dense, nnz(L)-sized sparse, one extra per live line-search trial —
/// `cggm::factor::dense_factor_bytes` and friends) come on top and are
/// measured by `MemBudget::peak()`, which the workspace arena and
/// budget-tracked factorization keep honest (asserted within tolerance by
/// `workspace_peak_matches_dense_estimate` and the `memwall_tests`
/// integration module).
pub fn dense_workingset_bytes(kind: SolverKind, p: usize, q: usize) -> usize {
    let f = std::mem::size_of::<f64>();
    match kind {
        // S_yy, Σ, Ψ, W(=Uᵀ) : q²; S_xx: p²; Vᵀ: pq.
        SolverKind::AltNewtonCd => f * (4 * q * q + p * p + p * q),
        // + Γ and Γᵀ (pq), V'ᵀ (pq).
        SolverKind::NewtonCd => f * (4 * q * q + p * p + 4 * p * q),
        SolverKind::AltNewtonBcd => 0, // governed by the budget instead
        // Dense iterates + Γ: q² ×4 + pq ×3 (x, y, grads) + p² is avoided.
        SolverKind::ProxGrad => f * (4 * q * q + 3 * p * q),
    }
}
