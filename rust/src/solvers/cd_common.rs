//! Shared coordinate-descent inner loops (dense-cache variants used by the
//! two non-block solvers). Update equations are derived in DESIGN.md §4
//! (note the erratum on the paper's `a` coefficient).
//!
//! These passes are pure compute over caller-provided buffers: they never
//! allocate, so the solvers can (and do) hand them matrices checked out of
//! the [`super::workspace::Workspace`] arena — `syy` comes from the
//! [`super::SolverContext`] statistic cache, `w`/`vt`/`vtp` are arena
//! checkouts recycled across iterations.
//!
//! Layout conventions (performance-critical — see DESIGN.md §9):
//! - `sigma`, `psi`, `syy` are dense symmetric q×q, so row i ≡ column i;
//! - `w` stores **Uᵀ = (Δ_ΛΣ)ᵀ = ΣΔ_Λ**: `w.row(t)` is the t-th *column* of
//!   U, making every Hessian dot a contiguous-row dot;
//! - `vt` stores **Vᵀ = (ΘΣ)ᵀ = ΣΘᵀ**: `vt.row(j)` is the j-th column of V.

use crate::cggm::cd_minimizer;
use crate::linalg::dense::{dot, Mat};
use crate::linalg::sparse::SpRowMat;

/// Extra cached matrices for the joint (Newton CD) Λ update: the Γ/Φ
/// coupling terms of Appendix A.1.
pub struct JointTerms<'a> {
    /// Γᵀ (q×p): `gamma_t.row(i)` = Γ_:,i.
    pub gamma_t: &'a Mat,
    /// V'ᵀ = (Δ_ΘΣ)ᵀ (q×p): `vtp.row(j)` = V'_:,j.
    pub vtp: &'a Mat,
}

/// One CD pass over the Λ active set, updating the direction `delta`
/// (symmetric) and the cache `w`. Returns the number of coordinates moved.
#[allow(clippy::too_many_arguments)]
pub fn lambda_cd_pass(
    active: &[(usize, usize)],
    syy: &Mat,
    sigma: &Mat,
    psi: &Mat,
    lambda: &SpRowMat,
    delta: &mut SpRowMat,
    w: &mut Mat,
    lam_l: f64,
    joint: Option<&JointTerms>,
) -> usize {
    let q = sigma.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let (s_ij, s_ii, s_jj) = (sigma[(i, j)], sigma[(i, i)], sigma[(j, j)]);
        let (p_ij, p_ii, p_jj) = (psi[(i, j)], psi[(i, i)], psi[(j, j)]);
        let mu = if i == j {
            let a = s_ii * s_ii + 2.0 * s_ii * p_ii;
            let mut b = syy[(i, i)] - s_ii - p_ii
                + dot(sigma.row(i), w.row(i))
                + 2.0 * dot(psi.row(i), w.row(i));
            if let Some(jt) = joint {
                b -= 2.0 * dot(jt.gamma_t.row(i), jt.vtp.row(i));
            }
            let c = lambda.get(i, i) + delta.get(i, i);
            cd_minimizer(a, b, c, lam_l)
        } else {
            let a = s_ij * s_ij + s_ii * s_jj + s_ii * p_jj + s_jj * p_ii + 2.0 * s_ij * p_ij;
            let mut b = syy[(i, j)] - s_ij - p_ij
                + dot(sigma.row(i), w.row(j))
                + dot(psi.row(i), w.row(j))
                + dot(psi.row(j), w.row(i));
            if let Some(jt) = joint {
                // Φ_ij + Φ_ji
                b -= dot(jt.gamma_t.row(i), jt.vtp.row(j))
                    + dot(jt.gamma_t.row(j), jt.vtp.row(i));
            }
            let c = lambda.get(i, j) + delta.get(i, j);
            cd_minimizer(a, b, c, lam_l)
        };
        if mu != 0.0 {
            moved += 1;
            delta.add_sym(i, j, mu);
            // Maintain w = Uᵀ: U_{i,:} += μΣ_{j,:} and U_{j,:} += μΣ_{i,:}
            // ⇒ column updates w[t][i] += μΣ[j][t], w[t][j] += μΣ[i][t].
            let wd = w.data_mut();
            let sd = sigma.data();
            if i == j {
                for t in 0..q {
                    wd[t * q + i] += mu * sd[i * q + t];
                }
            } else {
                for t in 0..q {
                    let sjt = sd[j * q + t];
                    let sit = sd[i * q + t];
                    wd[t * q + i] += mu * sjt;
                    wd[t * q + j] += mu * sit;
                }
            }
        }
    }
    moved
}

/// One CD pass over the Θ active set for **Algorithm 1's direct update**:
/// mutates Θ itself (and `vt = (ΘΣ)ᵀ`). `sxx_diag[i] = (S_xx)_ii`.
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direct(
    active: &[(usize, usize)],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    theta: &mut SpRowMat,
    vt: &mut Mat,
    lam_t: f64,
) -> usize {
    let q = sigma.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let a = 2.0 * sxx_diag[i] * sigma[(j, j)];
        if a <= 0.0 {
            continue; // zero-variance input: coordinate has no curvature
        }
        let b = 2.0 * sxy[(i, j)] + 2.0 * dot(sxx.row(i), vt.row(j));
        let c = theta.get(i, j);
        let mu = cd_minimizer(a, b, c, lam_t);
        if mu != 0.0 {
            moved += 1;
            theta.add(i, j, mu);
            // V_{i,:} += μ Σ_{j,:}  ⇒  vt[t][i] += μ Σ[j][t].
            let vd = vt.data_mut();
            let sd = sigma.data();
            let p = sxx.rows();
            for t in 0..q {
                vd[t * p + i] += mu * sd[j * q + t];
            }
        }
    }
    moved
}

/// One CD pass over the Θ active set for the **joint direction** (Newton CD
/// baseline, Appendix A.1): updates the direction `delta_t` and
/// `vtp = (Δ_ΘΣ)ᵀ`. Needs Γ (p×q, rows) and `w = (Δ_ΛΣ)ᵀ` for the coupling.
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direction(
    active: &[(usize, usize)],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    gamma: &Mat,
    w: &Mat,
    theta: &SpRowMat,
    delta_t: &mut SpRowMat,
    vtp: &mut Mat,
    lam_t: f64,
) -> usize {
    let q = sigma.rows();
    let p = sxx.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let a = 2.0 * sxx_diag[i] * sigma[(j, j)];
        if a <= 0.0 {
            continue;
        }
        let b = 2.0 * sxy[(i, j)] + 2.0 * gamma[(i, j)]
            + 2.0 * dot(sxx.row(i), vtp.row(j))
            - 2.0 * dot(gamma.row(i), w.row(j));
        let c = theta.get(i, j) + delta_t.get(i, j);
        let mu = cd_minimizer(a, b, c, lam_t);
        if mu != 0.0 {
            moved += 1;
            delta_t.add(i, j, mu);
            let vd = vtp.data_mut();
            let sd = sigma.data();
            for t in 0..q {
                vd[t * p + i] += mu * sd[j * q + t];
            }
        }
    }
    moved
}

/// tr(Gᵀ D) for dense G and sparse D (δ term of the Armijo condition).
pub fn trace_grad_dir(grad: &Mat, dir: &SpRowMat) -> f64 {
    let mut t = 0.0;
    for i in 0..dir.rows() {
        for &(j, v) in dir.row(i) {
            t += grad[(i, j)] * v;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::gemm::GemmEngine;
    use crate::util::rng::Rng;
    use crate::util::testing::property;

    /// Quadratic model value for the Λ subproblem:
    /// Q(Δ) = tr(∇ᵀΔ) + ½[tr(ΣΔΣΔ) + 2 tr(ΨΔΣΔ)] + λ‖Λ+Δ‖₁
    fn lambda_model_value(
        grad: &Mat,
        sigma: &Mat,
        psi: &Mat,
        lambda: &SpRowMat,
        delta: &SpRowMat,
        lam_l: f64,
    ) -> f64 {
        let q = sigma.rows();
        let eng = NativeGemm::new(1);
        let d = delta.to_dense();
        let mut ds = Mat::zeros(q, q);
        eng.gemm(1.0, &d, sigma, 0.0, &mut ds); // ΔΣ
        let mut sds = Mat::zeros(q, q);
        eng.gemm(1.0, sigma, &ds, 0.0, &mut sds); // ΣΔΣ
        let mut pds = Mat::zeros(q, q);
        eng.gemm(1.0, psi, &ds, 0.0, &mut pds); // ΨΔΣ
        let mut quad = 0.0;
        let mut lin = 0.0;
        for i in 0..q {
            for j in 0..q {
                quad += d[(i, j)] * (sds[(j, i)] + 2.0 * pds[(j, i)]);
                lin += grad[(i, j)] * d[(i, j)];
            }
        }
        let mut lpd = lambda.clone();
        lpd.add_scaled(1.0, delta);
        lin + 0.5 * quad + lam_l * lpd.l1_norm()
    }

    fn random_spd_dense(rng: &mut Rng, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = Mat::zeros(n, n);
        NativeGemm::new(1).gemm_tn(1.0, &b, &b, 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64 * 0.5;
        }
        a.symmetrize();
        a
    }

    fn random_psd_dense(rng: &mut Rng, n: usize, k: usize) -> Mat {
        let b = Mat::from_fn(k, n, |_, _| rng.normal());
        let mut a = Mat::zeros(n, n);
        NativeGemm::new(1).gemm_tn(1.0, &b, &b, 0.0, &mut a);
        a.symmetrize();
        a
    }

    #[test]
    fn lambda_cd_never_increases_model() {
        property(25, |rng| {
            let q = 2 + rng.below(7);
            let sigma = random_spd_dense(rng, q);
            let psi = random_psd_dense(rng, q, 3);
            let syy = random_psd_dense(rng, q, q + 2);
            let mut lambda = SpRowMat::eye(q);
            for _ in 0..q {
                let (i, j) = (rng.below(q), rng.below(q));
                lambda.set_sym(i, j, 0.1 * rng.normal());
            }
            for i in 0..q {
                lambda.add(i, i, 1.0);
            }
            // grad = S_yy - Σ - Ψ
            let mut grad = syy.clone();
            grad.add_scaled(-1.0, &sigma);
            grad.add_scaled(-1.0, &psi);
            let lam_l = 0.3;
            // active set: everything upper-tri
            let mut active = Vec::new();
            for i in 0..q {
                for j in i..q {
                    active.push((i, j));
                }
            }
            let mut delta = SpRowMat::zeros(q, q);
            let mut w = Mat::zeros(q, q);
            let mut prev = lambda_model_value(&grad, &sigma, &psi, &lambda, &delta, lam_l);
            for sweep in 0..3 {
                lambda_cd_pass(
                    &active, &syy, &sigma, &psi, &lambda, &mut delta, &mut w, lam_l, None,
                );
                let cur = lambda_model_value(&grad, &sigma, &psi, &lambda, &delta, lam_l);
                if cur > prev + 1e-9 {
                    return Err(format!("model increased on sweep {sweep}: {prev} -> {cur}"));
                }
                prev = cur;
            }
            // And the final model value beats Δ = 0.
            let zero = lambda_model_value(&grad, &sigma, &psi, &lambda, &SpRowMat::zeros(q, q), lam_l);
            if prev > zero + 1e-9 {
                return Err(format!("no progress over Δ=0: {prev} vs {zero}"));
            }
            Ok(())
        });
    }

    #[test]
    fn w_cache_stays_consistent() {
        // After a pass, w must equal (ΔΣ)ᵀ exactly.
        property(25, |rng| {
            let q = 2 + rng.below(7);
            let sigma = random_spd_dense(rng, q);
            let psi = random_psd_dense(rng, q, 2);
            let syy = random_psd_dense(rng, q, q);
            let lambda = SpRowMat::eye(q);
            let mut active = Vec::new();
            for i in 0..q {
                for j in i..q {
                    if rng.bernoulli(0.7) {
                        active.push((i, j));
                    }
                }
            }
            let mut delta = SpRowMat::zeros(q, q);
            let mut w = Mat::zeros(q, q);
            lambda_cd_pass(&active, &syy, &sigma, &psi, &lambda, &mut delta, &mut w, 0.1, None);
            let eng = NativeGemm::new(1);
            let d = delta.to_dense();
            let mut ds = Mat::zeros(q, q);
            eng.gemm(1.0, &d, &sigma, 0.0, &mut ds);
            let dst = ds.transposed();
            crate::util::testing::check_all_close(w.data(), dst.data(), 1e-9, "w = (ΔΣ)ᵀ")
        });
    }

    /// Θ subproblem objective: tr(2S_xyᵀΘ + ΣΘᵀS_xxΘ) + λ‖Θ‖₁.
    fn theta_obj(sxy: &Mat, sxx: &Mat, sigma: &Mat, theta: &SpRowMat, lam_t: f64) -> f64 {
        let eng = NativeGemm::new(1);
        let (p, q) = (sxx.rows(), sigma.rows());
        let td = theta.to_dense();
        let mut lin = 0.0;
        for i in 0..p {
            for j in 0..q {
                lin += sxy[(i, j)] * td[(i, j)];
            }
        }
        let mut st = Mat::zeros(p, q);
        eng.gemm(1.0, sxx, &td, 0.0, &mut st);
        let mut tst = Mat::zeros(q, q);
        eng.gemm_tn(1.0, &td, &st, 0.0, &mut tst);
        let mut quad = 0.0;
        for i in 0..q {
            for j in 0..q {
                quad += sigma[(i, j)] * tst[(j, i)];
            }
        }
        2.0 * lin + quad + lam_t * theta.l1_norm()
    }

    #[test]
    fn theta_cd_monotone_and_consistent() {
        property(25, |rng| {
            let p = 2 + rng.below(6);
            let q = 2 + rng.below(6);
            let sigma = random_spd_dense(rng, q);
            let sxx = random_spd_dense(rng, p);
            let sxy = Mat::from_fn(p, q, |_, _| rng.normal());
            let sxx_diag: Vec<f64> = (0..p).map(|i| sxx[(i, i)]).collect();
            let mut theta = SpRowMat::zeros(p, q);
            let mut vt = Mat::zeros(q, p);
            let mut active = Vec::new();
            for i in 0..p {
                for j in 0..q {
                    if rng.bernoulli(0.8) {
                        active.push((i, j));
                    }
                }
            }
            let lam_t = 0.2;
            let mut prev = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
            for sweep in 0..4 {
                theta_cd_pass_direct(
                    &active, &sxx, &sxx_diag, &sxy, &sigma, &mut theta, &mut vt, lam_t,
                );
                let cur = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
                if cur > prev + 1e-9 {
                    return Err(format!("Θ objective increased on sweep {sweep}"));
                }
                prev = cur;
            }
            // vt consistency: vt = (ΘΣ)ᵀ
            let eng = NativeGemm::new(1);
            let td = theta.to_dense();
            let mut v = Mat::zeros(p, q);
            eng.gemm(1.0, &td, &sigma, 0.0, &mut v);
            let vtt = v.transposed();
            crate::util::testing::check_all_close(vt.data(), vtt.data(), 1e-9, "vt = (ΘΣ)ᵀ")
        });
    }

    #[test]
    fn trace_grad_dir_matches_dense() {
        let mut rng = Rng::new(5);
        let g = Mat::from_fn(4, 4, |_, _| rng.normal());
        let mut d = SpRowMat::zeros(4, 4);
        d.set(1, 2, 2.0);
        d.set(3, 0, -1.0);
        assert!((trace_grad_dir(&g, &d) - (2.0 * g[(1, 2)] - g[(3, 0)])).abs() < 1e-14);
    }
}
