//! Shared coordinate-descent inner loops (dense-cache variants used by the
//! two non-block solvers). Update equations are derived in DESIGN.md §4
//! (note the erratum on the paper's `a` coefficient).
//!
//! These passes are pure compute over caller-provided buffers: they never
//! allocate, so the solvers can (and do) hand them matrices checked out of
//! the [`super::workspace::Workspace`] arena — `syy` comes from the
//! [`super::SolverContext`] statistic cache, `w`/`vt`/`vtp` are arena
//! checkouts recycled across iterations.
//!
//! Layout conventions (performance-critical — see DESIGN.md §9):
//! - `sigma`, `psi`, `syy` are dense symmetric q×q, so row i ≡ column i;
//! - `w` stores **Uᵀ = (Δ_ΛΣ)ᵀ = ΣΔ_Λ**: `w.row(t)` is the t-th *column* of
//!   U, making every Hessian dot a contiguous-row dot;
//! - `vt` stores **Vᵀ = (ΘΣ)ᵀ = ΣΘᵀ**: `vt.row(j)` is the j-th column of V.

use crate::cggm::cd_minimizer;
use crate::linalg::dense::{dot, Mat};
use crate::linalg::sparse::SpRowMat;
use crate::util::threadpool::{Parallelism, SharedMut, SharedSlice};

/// Extra cached matrices for the joint (Newton CD) Λ update: the Γ/Φ
/// coupling terms of Appendix A.1.
pub struct JointTerms<'a> {
    /// Γᵀ (q×p): `gamma_t.row(i)` = Γ_:,i.
    pub gamma_t: &'a Mat,
    /// V'ᵀ = (Δ_ΘΣ)ᵀ (q×p): `vtp.row(j)` = V'_:,j.
    pub vtp: &'a Mat,
}

/// Reusable scratch for the colored (thread-parallel) CD passes: the
/// per-class step-value slots every team member reads after the phase-1
/// barrier. Kept by the solvers across iterations so the colored loops
/// allocate only on first use.
#[derive(Default)]
pub struct ColoredScratch {
    mu: Vec<f64>,
}

/// One coordinate's Λ CD step at the *current* (Δ, w) state — the shared
/// math of the serial and colored passes. `w_i`/`w_j` are rows i and j of
/// the `w = Uᵀ` cache (passed as slices so the colored pass can read them
/// through its shared phase view).
#[allow(clippy::too_many_arguments)]
#[inline]
fn lambda_coord_mu(
    i: usize,
    j: usize,
    syy: &Mat,
    sigma: &Mat,
    psi: &Mat,
    lambda: &SpRowMat,
    delta: &SpRowMat,
    w_i: &[f64],
    w_j: &[f64],
    lam_l: f64,
    joint: Option<&JointTerms>,
) -> f64 {
    let (s_ij, s_ii, s_jj) = (sigma[(i, j)], sigma[(i, i)], sigma[(j, j)]);
    let (p_ij, p_ii, p_jj) = (psi[(i, j)], psi[(i, i)], psi[(j, j)]);
    if i == j {
        let a = s_ii * s_ii + 2.0 * s_ii * p_ii;
        let mut b =
            syy[(i, i)] - s_ii - p_ii + dot(sigma.row(i), w_i) + 2.0 * dot(psi.row(i), w_i);
        if let Some(jt) = joint {
            b -= 2.0 * dot(jt.gamma_t.row(i), jt.vtp.row(i));
        }
        let c = lambda.get(i, i) + delta.get(i, i);
        cd_minimizer(a, b, c, lam_l)
    } else {
        let a = s_ij * s_ij + s_ii * s_jj + s_ii * p_jj + s_jj * p_ii + 2.0 * s_ij * p_ij;
        let mut b = syy[(i, j)] - s_ij - p_ij
            + dot(sigma.row(i), w_j)
            + dot(psi.row(i), w_j)
            + dot(psi.row(j), w_i);
        if let Some(jt) = joint {
            // Φ_ij + Φ_ji
            b -= dot(jt.gamma_t.row(i), jt.vtp.row(j)) + dot(jt.gamma_t.row(j), jt.vtp.row(i));
        }
        let c = lambda.get(i, j) + delta.get(i, j);
        cd_minimizer(a, b, c, lam_l)
    }
}

/// One coordinate's Θ step for Algorithm 1's direct update (0.0 when the
/// coordinate has no curvature). `vt_j` is row j of the `vt = (ΘΣ)ᵀ` cache.
#[allow(clippy::too_many_arguments)]
#[inline]
fn theta_direct_mu(
    i: usize,
    j: usize,
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    theta: &SpRowMat,
    vt_j: &[f64],
    lam_t: f64,
) -> f64 {
    let a = 2.0 * sxx_diag[i] * sigma[(j, j)];
    if a <= 0.0 {
        return 0.0; // zero-variance input: coordinate has no curvature
    }
    let b = 2.0 * sxy[(i, j)] + 2.0 * dot(sxx.row(i), vt_j);
    let c = theta.get(i, j);
    cd_minimizer(a, b, c, lam_t)
}

/// One coordinate's Θ step for the joint direction (Newton CD baseline).
/// `vtp_j` is row j of the `vtp = (Δ_ΘΣ)ᵀ` cache.
#[allow(clippy::too_many_arguments)]
#[inline]
fn theta_direction_mu(
    i: usize,
    j: usize,
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    gamma: &Mat,
    w: &Mat,
    theta: &SpRowMat,
    delta_t: &SpRowMat,
    vtp_j: &[f64],
    lam_t: f64,
) -> f64 {
    let a = 2.0 * sxx_diag[i] * sigma[(j, j)];
    if a <= 0.0 {
        return 0.0;
    }
    let b = 2.0 * sxy[(i, j)] + 2.0 * gamma[(i, j)] + 2.0 * dot(sxx.row(i), vtp_j)
        - 2.0 * dot(gamma.row(i), w.row(j));
    let c = theta.get(i, j) + delta_t.get(i, j);
    cd_minimizer(a, b, c, lam_t)
}

/// One CD pass over the Λ active set, updating the direction `delta`
/// (symmetric) and the cache `w`. Returns the number of coordinates moved.
#[allow(clippy::too_many_arguments)]
pub fn lambda_cd_pass(
    active: &[(usize, usize)],
    syy: &Mat,
    sigma: &Mat,
    psi: &Mat,
    lambda: &SpRowMat,
    delta: &mut SpRowMat,
    w: &mut Mat,
    lam_l: f64,
    joint: Option<&JointTerms>,
) -> usize {
    let q = sigma.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let mu = lambda_coord_mu(
            i,
            j,
            syy,
            sigma,
            psi,
            lambda,
            delta,
            w.row(i),
            w.row(j),
            lam_l,
            joint,
        );
        if mu != 0.0 {
            moved += 1;
            delta.add_sym(i, j, mu);
            // Maintain w = Uᵀ: U_{i,:} += μΣ_{j,:} and U_{j,:} += μΣ_{i,:}
            // ⇒ column updates w[t][i] += μΣ[j][t], w[t][j] += μΣ[i][t].
            let wd = w.data_mut();
            let sd = sigma.data();
            if i == j {
                for t in 0..q {
                    wd[t * q + i] += mu * sd[i * q + t];
                }
            } else {
                for t in 0..q {
                    let sjt = sd[j * q + t];
                    let sit = sd[i * q + t];
                    wd[t * q + i] += mu * sjt;
                    wd[t * q + j] += mu * sit;
                }
            }
        }
    }
    moved
}

/// One CD pass over the Θ active set for **Algorithm 1's direct update**:
/// mutates Θ itself (and `vt = (ΘΣ)ᵀ`). `sxx_diag[i] = (S_xx)_ii`.
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direct(
    active: &[(usize, usize)],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    theta: &mut SpRowMat,
    vt: &mut Mat,
    lam_t: f64,
) -> usize {
    let q = sigma.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let mu = theta_direct_mu(i, j, sxx, sxx_diag, sxy, sigma, theta, vt.row(j), lam_t);
        if mu != 0.0 {
            moved += 1;
            theta.add(i, j, mu);
            // V_{i,:} += μ Σ_{j,:}  ⇒  vt[t][i] += μ Σ[j][t].
            let vd = vt.data_mut();
            let sd = sigma.data();
            let p = sxx.rows();
            for t in 0..q {
                vd[t * p + i] += mu * sd[j * q + t];
            }
        }
    }
    moved
}

/// One CD pass over the Θ active set for the **joint direction** (Newton CD
/// baseline, Appendix A.1): updates the direction `delta_t` and
/// `vtp = (Δ_ΘΣ)ᵀ`. Needs Γ (p×q, rows) and `w = (Δ_ΛΣ)ᵀ` for the coupling.
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direction(
    active: &[(usize, usize)],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    gamma: &Mat,
    w: &Mat,
    theta: &SpRowMat,
    delta_t: &mut SpRowMat,
    vtp: &mut Mat,
    lam_t: f64,
) -> usize {
    let q = sigma.rows();
    let p = sxx.rows();
    let mut moved = 0usize;
    for &(i, j) in active {
        let mu = theta_direction_mu(
            i,
            j,
            sxx,
            sxx_diag,
            sxy,
            sigma,
            gamma,
            w,
            theta,
            delta_t,
            vtp.row(j),
            lam_t,
        );
        if mu != 0.0 {
            moved += 1;
            delta_t.add(i, j, mu);
            let vd = vtp.data_mut();
            let sd = sigma.data();
            for t in 0..q {
                vd[t * p + i] += mu * sd[j * q + t];
            }
        }
    }
    moved
}

// -------------------------------------------------- colored parallel passes
//
// The colored variants run Gauss–Seidel *across* color classes and
// data-parallel *within* a class (the classes come from
// `graph::coloring`: no two pairs in a class share a row/column index).
// One scoped team ([`Parallelism::team`]) processes all classes, with a
// barrier pair per class:
//
//   1. every pair's step μ is computed from the class-entry state (the
//      caches are frozen — read-only — into the shared `mu` slots, each
//      written by one thread) — `sync` —
//   2. every thread derives the identical nonzero-update list from `mu`;
//      thread 0 applies it to the sparse direction (O(1) per step) while
//      the dense ring cache is updated data-parallel across its *rows*
//      (each row applies every step in class order, so writes are disjoint
//      and the result is bitwise-identical for every thread count) —
//      `sync` — next class.
//
// Within a class this is a Jacobi step — sound because same-class pairs
// share no index, so their Hessian coupling is only the off-diagonal
// Σ/S_xx products; across classes it remains Gauss–Seidel. The solvers use
// these passes only when `SolveOptions::cd_threads > 1`, so the serial
// passes above stay the bit-exact single-thread reference.

/// Colored Λ CD pass over `classes` (see [`crate::graph::coloring`]).
/// Semantically matches [`lambda_cd_pass`] up to within-class Jacobi
/// ordering; bitwise-identical for every `par` thread count.
#[allow(clippy::too_many_arguments)]
pub fn lambda_cd_pass_colored(
    classes: &[Vec<(usize, usize)>],
    syy: &Mat,
    sigma: &Mat,
    psi: &Mat,
    lambda: &SpRowMat,
    delta: &mut SpRowMat,
    w: &mut Mat,
    lam_l: f64,
    joint: Option<&JointTerms>,
    par: &Parallelism,
    scratch: &mut ColoredScratch,
) -> usize {
    let q = sigma.rows();
    let maxc = classes.iter().map(|c| c.len()).max().unwrap_or(0);
    if maxc == 0 {
        return 0;
    }
    scratch.mu.clear();
    scratch.mu.resize(maxc, 0.0);
    let moved = std::sync::atomic::AtomicUsize::new(0);
    let mu_shared = SharedSlice::new(&mut scratch.mu);
    let w_shared = SharedSlice::new(w.data_mut());
    let delta_shared = SharedMut::new(delta);
    let sd = sigma.data();
    par.team(|tid, team| {
        let nt = team.threads();
        let mut upd: Vec<(usize, usize, f64)> = Vec::new();
        for class in classes {
            let m = class.len();
            {
                // Phase 1 — SAFETY: nothing writes w/delta until the
                // barrier; each mu slot is written by exactly one thread.
                let w_ro = unsafe { w_shared.slice(0, q * q) };
                let delta_ro = unsafe { delta_shared.get_ref() };
                for k in (tid..m).step_by(nt) {
                    let (i, j) = class[k];
                    let mu = lambda_coord_mu(
                        i,
                        j,
                        syy,
                        sigma,
                        psi,
                        lambda,
                        delta_ro,
                        &w_ro[i * q..(i + 1) * q],
                        &w_ro[j * q..(j + 1) * q],
                        lam_l,
                        joint,
                    );
                    unsafe { mu_shared.write(k, mu) };
                }
            }
            team.sync();
            // Phase 2: identical update list on every thread (no second
            // rendezvous needed to share it).
            upd.clear();
            {
                let mu_ro = unsafe { mu_shared.slice(0, m) };
                for (k, &(i, j)) in class.iter().enumerate() {
                    if mu_ro[k] != 0.0 {
                        upd.push((i, j, mu_ro[k]));
                    }
                }
            }
            if !upd.is_empty() {
                if tid == 0 {
                    moved.fetch_add(upd.len(), std::sync::atomic::Ordering::Relaxed);
                    // SAFETY: only thread 0 touches delta during phase 2.
                    let delta_mut = unsafe { delta_shared.get_mut() };
                    for &(i, j, mu) in &upd {
                        delta_mut.add_sym(i, j, mu);
                    }
                }
                for t in (tid..q).step_by(nt) {
                    // SAFETY: row t is written by exactly one thread.
                    let wrow = unsafe { w_shared.slice_mut(t * q, q) };
                    for &(i, j, mu) in &upd {
                        if i == j {
                            wrow[i] += mu * sd[i * q + t];
                        } else {
                            wrow[i] += mu * sd[j * q + t];
                            wrow[j] += mu * sd[i * q + t];
                        }
                    }
                }
            }
            team.sync();
        }
    });
    moved.into_inner()
}

/// Colored Θ pass for Algorithm 1's direct update; parallel counterpart of
/// [`theta_cd_pass_direct`].
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direct_colored(
    classes: &[Vec<(usize, usize)>],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    theta: &mut SpRowMat,
    vt: &mut Mat,
    lam_t: f64,
    par: &Parallelism,
    scratch: &mut ColoredScratch,
) -> usize {
    let q = sigma.rows();
    let p = sxx.rows();
    let maxc = classes.iter().map(|c| c.len()).max().unwrap_or(0);
    if maxc == 0 {
        return 0;
    }
    scratch.mu.clear();
    scratch.mu.resize(maxc, 0.0);
    let moved = std::sync::atomic::AtomicUsize::new(0);
    let mu_shared = SharedSlice::new(&mut scratch.mu);
    let vt_shared = SharedSlice::new(vt.data_mut());
    let theta_shared = SharedMut::new(theta);
    let sd = sigma.data();
    par.team(|tid, team| {
        let nt = team.threads();
        let mut upd: Vec<(usize, usize, f64)> = Vec::new();
        for class in classes {
            let m = class.len();
            {
                // Phase 1 — SAFETY: vt/theta are read-only until the barrier.
                let vt_ro = unsafe { vt_shared.slice(0, q * p) };
                let theta_ro = unsafe { theta_shared.get_ref() };
                for k in (tid..m).step_by(nt) {
                    let (i, j) = class[k];
                    let mu = theta_direct_mu(
                        i,
                        j,
                        sxx,
                        sxx_diag,
                        sxy,
                        sigma,
                        theta_ro,
                        &vt_ro[j * p..(j + 1) * p],
                        lam_t,
                    );
                    unsafe { mu_shared.write(k, mu) };
                }
            }
            team.sync();
            upd.clear();
            {
                let mu_ro = unsafe { mu_shared.slice(0, m) };
                for (k, &(i, j)) in class.iter().enumerate() {
                    if mu_ro[k] != 0.0 {
                        upd.push((i, j, mu_ro[k]));
                    }
                }
            }
            if !upd.is_empty() {
                if tid == 0 {
                    moved.fetch_add(upd.len(), std::sync::atomic::Ordering::Relaxed);
                    // SAFETY: only thread 0 touches Θ during phase 2.
                    let theta_mut = unsafe { theta_shared.get_mut() };
                    for &(i, j, mu) in &upd {
                        theta_mut.add(i, j, mu);
                    }
                }
                for t in (tid..q).step_by(nt) {
                    // SAFETY: row t is written by exactly one thread.
                    let vrow = unsafe { vt_shared.slice_mut(t * p, p) };
                    for &(i, j, mu) in &upd {
                        vrow[i] += mu * sd[j * q + t];
                    }
                }
            }
            team.sync();
        }
    });
    moved.into_inner()
}

/// Colored Θ pass for the joint direction; parallel counterpart of
/// [`theta_cd_pass_direction`].
#[allow(clippy::too_many_arguments)]
pub fn theta_cd_pass_direction_colored(
    classes: &[Vec<(usize, usize)>],
    sxx: &Mat,
    sxx_diag: &[f64],
    sxy: &Mat,
    sigma: &Mat,
    gamma: &Mat,
    w: &Mat,
    theta: &SpRowMat,
    delta_t: &mut SpRowMat,
    vtp: &mut Mat,
    lam_t: f64,
    par: &Parallelism,
    scratch: &mut ColoredScratch,
) -> usize {
    let q = sigma.rows();
    let p = sxx.rows();
    let maxc = classes.iter().map(|c| c.len()).max().unwrap_or(0);
    if maxc == 0 {
        return 0;
    }
    scratch.mu.clear();
    scratch.mu.resize(maxc, 0.0);
    let moved = std::sync::atomic::AtomicUsize::new(0);
    let mu_shared = SharedSlice::new(&mut scratch.mu);
    let vtp_shared = SharedSlice::new(vtp.data_mut());
    let dt_shared = SharedMut::new(delta_t);
    let sd = sigma.data();
    par.team(|tid, team| {
        let nt = team.threads();
        let mut upd: Vec<(usize, usize, f64)> = Vec::new();
        for class in classes {
            let m = class.len();
            {
                // Phase 1 — SAFETY: vtp/delta_t are read-only until the
                // barrier.
                let vtp_ro = unsafe { vtp_shared.slice(0, q * p) };
                let dt_ro = unsafe { dt_shared.get_ref() };
                for k in (tid..m).step_by(nt) {
                    let (i, j) = class[k];
                    let mu = theta_direction_mu(
                        i,
                        j,
                        sxx,
                        sxx_diag,
                        sxy,
                        sigma,
                        gamma,
                        w,
                        theta,
                        dt_ro,
                        &vtp_ro[j * p..(j + 1) * p],
                        lam_t,
                    );
                    unsafe { mu_shared.write(k, mu) };
                }
            }
            team.sync();
            upd.clear();
            {
                let mu_ro = unsafe { mu_shared.slice(0, m) };
                for (k, &(i, j)) in class.iter().enumerate() {
                    if mu_ro[k] != 0.0 {
                        upd.push((i, j, mu_ro[k]));
                    }
                }
            }
            if !upd.is_empty() {
                if tid == 0 {
                    moved.fetch_add(upd.len(), std::sync::atomic::Ordering::Relaxed);
                    // SAFETY: only thread 0 touches Δ_Θ during phase 2.
                    let dt_mut = unsafe { dt_shared.get_mut() };
                    for &(i, j, mu) in &upd {
                        dt_mut.add(i, j, mu);
                    }
                }
                for t in (tid..q).step_by(nt) {
                    // SAFETY: row t is written by exactly one thread.
                    let vrow = unsafe { vtp_shared.slice_mut(t * p, p) };
                    for &(i, j, mu) in &upd {
                        vrow[i] += mu * sd[j * q + t];
                    }
                }
            }
            team.sync();
        }
    });
    moved.into_inner()
}

/// tr(Gᵀ D) for dense G and sparse D (δ term of the Armijo condition).
pub fn trace_grad_dir(grad: &Mat, dir: &SpRowMat) -> f64 {
    let mut t = 0.0;
    for i in 0..dir.rows() {
        for &(j, v) in dir.row(i) {
            t += grad[(i, j)] * v;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::NativeGemm;
    use crate::gemm::GemmEngine;
    use crate::util::rng::Rng;
    use crate::util::testing::property;

    /// Quadratic model value for the Λ subproblem:
    /// Q(Δ) = tr(∇ᵀΔ) + ½[tr(ΣΔΣΔ) + 2 tr(ΨΔΣΔ)] + λ‖Λ+Δ‖₁
    fn lambda_model_value(
        grad: &Mat,
        sigma: &Mat,
        psi: &Mat,
        lambda: &SpRowMat,
        delta: &SpRowMat,
        lam_l: f64,
    ) -> f64 {
        let q = sigma.rows();
        let eng = NativeGemm::new(1);
        let d = delta.to_dense();
        let mut ds = Mat::zeros(q, q);
        eng.gemm(1.0, &d, sigma, 0.0, &mut ds); // ΔΣ
        let mut sds = Mat::zeros(q, q);
        eng.gemm(1.0, sigma, &ds, 0.0, &mut sds); // ΣΔΣ
        let mut pds = Mat::zeros(q, q);
        eng.gemm(1.0, psi, &ds, 0.0, &mut pds); // ΨΔΣ
        let mut quad = 0.0;
        let mut lin = 0.0;
        for i in 0..q {
            for j in 0..q {
                quad += d[(i, j)] * (sds[(j, i)] + 2.0 * pds[(j, i)]);
                lin += grad[(i, j)] * d[(i, j)];
            }
        }
        let mut lpd = lambda.clone();
        lpd.add_scaled(1.0, delta);
        lin + 0.5 * quad + lam_l * lpd.l1_norm()
    }

    fn random_spd_dense(rng: &mut Rng, n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = Mat::zeros(n, n);
        NativeGemm::new(1).gemm_tn(1.0, &b, &b, 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64 * 0.5;
        }
        a.symmetrize();
        a
    }

    fn random_psd_dense(rng: &mut Rng, n: usize, k: usize) -> Mat {
        let b = Mat::from_fn(k, n, |_, _| rng.normal());
        let mut a = Mat::zeros(n, n);
        NativeGemm::new(1).gemm_tn(1.0, &b, &b, 0.0, &mut a);
        a.symmetrize();
        a
    }

    #[test]
    fn lambda_cd_never_increases_model() {
        property(25, |rng| {
            let q = 2 + rng.below(7);
            let sigma = random_spd_dense(rng, q);
            let psi = random_psd_dense(rng, q, 3);
            let syy = random_psd_dense(rng, q, q + 2);
            let mut lambda = SpRowMat::eye(q);
            for _ in 0..q {
                let (i, j) = (rng.below(q), rng.below(q));
                lambda.set_sym(i, j, 0.1 * rng.normal());
            }
            for i in 0..q {
                lambda.add(i, i, 1.0);
            }
            // grad = S_yy - Σ - Ψ
            let mut grad = syy.clone();
            grad.add_scaled(-1.0, &sigma);
            grad.add_scaled(-1.0, &psi);
            let lam_l = 0.3;
            // active set: everything upper-tri
            let mut active = Vec::new();
            for i in 0..q {
                for j in i..q {
                    active.push((i, j));
                }
            }
            let mut delta = SpRowMat::zeros(q, q);
            let mut w = Mat::zeros(q, q);
            let mut prev = lambda_model_value(&grad, &sigma, &psi, &lambda, &delta, lam_l);
            for sweep in 0..3 {
                lambda_cd_pass(
                    &active, &syy, &sigma, &psi, &lambda, &mut delta, &mut w, lam_l, None,
                );
                let cur = lambda_model_value(&grad, &sigma, &psi, &lambda, &delta, lam_l);
                if cur > prev + 1e-9 {
                    return Err(format!("model increased on sweep {sweep}: {prev} -> {cur}"));
                }
                prev = cur;
            }
            // And the final model value beats Δ = 0.
            let zero = lambda_model_value(&grad, &sigma, &psi, &lambda, &SpRowMat::zeros(q, q), lam_l);
            if prev > zero + 1e-9 {
                return Err(format!("no progress over Δ=0: {prev} vs {zero}"));
            }
            Ok(())
        });
    }

    #[test]
    fn w_cache_stays_consistent() {
        // After a pass, w must equal (ΔΣ)ᵀ exactly.
        property(25, |rng| {
            let q = 2 + rng.below(7);
            let sigma = random_spd_dense(rng, q);
            let psi = random_psd_dense(rng, q, 2);
            let syy = random_psd_dense(rng, q, q);
            let lambda = SpRowMat::eye(q);
            let mut active = Vec::new();
            for i in 0..q {
                for j in i..q {
                    if rng.bernoulli(0.7) {
                        active.push((i, j));
                    }
                }
            }
            let mut delta = SpRowMat::zeros(q, q);
            let mut w = Mat::zeros(q, q);
            lambda_cd_pass(&active, &syy, &sigma, &psi, &lambda, &mut delta, &mut w, 0.1, None);
            let eng = NativeGemm::new(1);
            let d = delta.to_dense();
            let mut ds = Mat::zeros(q, q);
            eng.gemm(1.0, &d, &sigma, 0.0, &mut ds);
            let dst = ds.transposed();
            crate::util::testing::check_all_close(w.data(), dst.data(), 1e-9, "w = (ΔΣ)ᵀ")
        });
    }

    /// Θ subproblem objective: tr(2S_xyᵀΘ + ΣΘᵀS_xxΘ) + λ‖Θ‖₁.
    fn theta_obj(sxy: &Mat, sxx: &Mat, sigma: &Mat, theta: &SpRowMat, lam_t: f64) -> f64 {
        let eng = NativeGemm::new(1);
        let (p, q) = (sxx.rows(), sigma.rows());
        let td = theta.to_dense();
        let mut lin = 0.0;
        for i in 0..p {
            for j in 0..q {
                lin += sxy[(i, j)] * td[(i, j)];
            }
        }
        let mut st = Mat::zeros(p, q);
        eng.gemm(1.0, sxx, &td, 0.0, &mut st);
        let mut tst = Mat::zeros(q, q);
        eng.gemm_tn(1.0, &td, &st, 0.0, &mut tst);
        let mut quad = 0.0;
        for i in 0..q {
            for j in 0..q {
                quad += sigma[(i, j)] * tst[(j, i)];
            }
        }
        2.0 * lin + quad + lam_t * theta.l1_norm()
    }

    #[test]
    fn theta_cd_monotone_and_consistent() {
        property(25, |rng| {
            let p = 2 + rng.below(6);
            let q = 2 + rng.below(6);
            let sigma = random_spd_dense(rng, q);
            let sxx = random_spd_dense(rng, p);
            let sxy = Mat::from_fn(p, q, |_, _| rng.normal());
            let sxx_diag: Vec<f64> = (0..p).map(|i| sxx[(i, i)]).collect();
            let mut theta = SpRowMat::zeros(p, q);
            let mut vt = Mat::zeros(q, p);
            let mut active = Vec::new();
            for i in 0..p {
                for j in 0..q {
                    if rng.bernoulli(0.8) {
                        active.push((i, j));
                    }
                }
            }
            let lam_t = 0.2;
            let mut prev = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
            for sweep in 0..4 {
                theta_cd_pass_direct(
                    &active, &sxx, &sxx_diag, &sxy, &sigma, &mut theta, &mut vt, lam_t,
                );
                let cur = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
                if cur > prev + 1e-9 {
                    return Err(format!("Θ objective increased on sweep {sweep}"));
                }
                prev = cur;
            }
            // vt consistency: vt = (ΘΣ)ᵀ
            let eng = NativeGemm::new(1);
            let td = theta.to_dense();
            let mut v = Mat::zeros(p, q);
            eng.gemm(1.0, &td, &sigma, 0.0, &mut v);
            let vtt = v.transposed();
            crate::util::testing::check_all_close(vt.data(), vtt.data(), 1e-9, "vt = (ΘΣ)ᵀ")
        });
    }

    #[test]
    fn colored_lambda_pass_keeps_w_consistent_and_descends() {
        // The colored pass must (a) keep w = (ΔΣ)ᵀ exact, (b) not increase
        // the quadratic model, and (c) be bitwise-identical across thread
        // counts.
        property(15, |rng| {
            let q = 3 + rng.below(10);
            let sigma = random_spd_dense(rng, q);
            let psi = random_psd_dense(rng, q, 3);
            let syy = random_psd_dense(rng, q, q + 2);
            let lambda = SpRowMat::eye(q);
            let mut active = Vec::new();
            for i in 0..q {
                for j in i..q {
                    if i == j || rng.bernoulli(0.6) {
                        active.push((i, j));
                    }
                }
            }
            let space = crate::graph::coloring::ConflictSpace::Symmetric(q);
            let classes = crate::graph::coloring::color_classes(&active, space);
            crate::graph::coloring::validate_classes(&active, &classes, space)?;
            let lam_l = 0.25;
            let grad = {
                let mut g = syy.clone();
                g.add_scaled(-1.0, &sigma);
                g.add_scaled(-1.0, &psi);
                g
            };
            let zero = lambda_model_value(&grad, &sigma, &psi, &lambda, &SpRowMat::zeros(q, q), lam_l);
            let mut results = Vec::new();
            for threads in [1usize, 2, 4] {
                let par = Parallelism::new(threads);
                let mut scratch = ColoredScratch::default();
                let mut delta = SpRowMat::zeros(q, q);
                let mut w = Mat::zeros(q, q);
                let mut prev = zero;
                for sweep in 0..3 {
                    lambda_cd_pass_colored(
                        &classes, &syy, &sigma, &psi, &lambda, &mut delta, &mut w, lam_l,
                        None, &par, &mut scratch,
                    );
                    let cur = lambda_model_value(&grad, &sigma, &psi, &lambda, &delta, lam_l);
                    // Within-class Jacobi may wiggle at rounding scale;
                    // anything beyond that slack is a real regression.
                    if cur > prev + 1e-7 * (1.0 + prev.abs()) {
                        return Err(format!(
                            "colored model increased (threads={threads} sweep={sweep}): \
                             {prev} -> {cur}"
                        ));
                    }
                    prev = cur;
                }
                // w = (ΔΣ)ᵀ exactly.
                let eng = NativeGemm::new(1);
                let d = delta.to_dense();
                let mut ds = Mat::zeros(q, q);
                eng.gemm(1.0, &d, &sigma, 0.0, &mut ds);
                let dst = ds.transposed();
                crate::util::testing::check_all_close(w.data(), dst.data(), 1e-9, "w = (ΔΣ)ᵀ")?;
                results.push((delta.to_dense(), w));
            }
            // Bitwise determinism across thread counts.
            for k in 1..results.len() {
                if results[0].0.data() != results[k].0.data()
                    || results[0].1.data() != results[k].1.data()
                {
                    return Err("colored pass not deterministic across thread counts".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn colored_theta_pass_matches_cache_invariant_and_is_deterministic() {
        property(15, |rng| {
            let p = 2 + rng.below(8);
            let q = 2 + rng.below(8);
            let sigma = random_spd_dense(rng, q);
            let sxx = random_spd_dense(rng, p);
            let sxy = Mat::from_fn(p, q, |_, _| rng.normal());
            let sxx_diag: Vec<f64> = (0..p).map(|i| sxx[(i, i)]).collect();
            let mut active = Vec::new();
            for i in 0..p {
                for j in 0..q {
                    if rng.bernoulli(0.7) {
                        active.push((i, j));
                    }
                }
            }
            let space = crate::graph::coloring::ConflictSpace::Bipartite(p, q);
            let classes = crate::graph::coloring::color_classes(&active, space);
            crate::graph::coloring::validate_classes(&active, &classes, space)?;
            let lam_t = 0.2;
            let mut outs = Vec::new();
            for threads in [1usize, 3] {
                let par = Parallelism::new(threads);
                let mut scratch = ColoredScratch::default();
                let mut theta = SpRowMat::zeros(p, q);
                let mut vt = Mat::zeros(q, p);
                let mut prev = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
                for sweep in 0..3 {
                    theta_cd_pass_direct_colored(
                        &classes, &sxx, &sxx_diag, &sxy, &sigma, &mut theta, &mut vt, lam_t,
                        &par, &mut scratch,
                    );
                    let cur = theta_obj(&sxy, &sxx, &sigma, &theta, lam_t);
                    if cur > prev + 1e-7 * (1.0 + prev.abs()) {
                        return Err(format!("Θ objective increased (sweep {sweep})"));
                    }
                    prev = cur;
                }
                // vt = (ΘΣ)ᵀ exactly.
                let eng = NativeGemm::new(1);
                let td = theta.to_dense();
                let mut v = Mat::zeros(p, q);
                eng.gemm(1.0, &td, &sigma, 0.0, &mut v);
                let vtt = v.transposed();
                crate::util::testing::check_all_close(vt.data(), vtt.data(), 1e-9, "vt")?;
                outs.push(theta.to_dense());
            }
            if outs[0].data() != outs[1].data() {
                return Err("colored Θ pass not deterministic across thread counts".into());
            }
            Ok(())
        });
    }

    #[test]
    fn trace_grad_dir_matches_dense() {
        let mut rng = Rng::new(5);
        let g = Mat::from_fn(4, 4, |_, _| rng.normal());
        let mut d = SpRowMat::zeros(4, 4);
        d.set(1, 2, 2.0);
        d.set(3, 0, -1.0);
        assert!((trace_grad_dir(&g, &d) - (2.0 * g[(1, 2)] - g[(3, 0)])).abs() < 1e-14);
    }
}
