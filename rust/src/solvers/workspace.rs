//! Budget-tracked workspace arena for the solvers' hot-loop buffers.
//!
//! Every solver iteration needs the same handful of dense scratch matrices
//! (Σ, Ψ, gradients, the `U`/`V` caches, GEMM panels). Allocating them with
//! `Mat::zeros` inside the loop has two costs the paper's speed story cannot
//! afford: allocator traffic on the hot path, and — worse for the memwall
//! experiment — memory the [`MemBudget`] never sees, so `peak()` under-reports
//! the true working set of the non-block solvers.
//!
//! [`Workspace`] fixes both. Buffers are checked out by shape
//! ([`Workspace::mat`] / [`Workspace::vec`]) and returned to a free pool when
//! the RAII guard ([`WsMat`] / [`WsVec`]) drops. Checkouts are tracked against
//! the budget for exactly as long as they are live, so
//! `MemBudget::peak()` reports the true concurrent working set; idle pooled
//! buffers are capacity held by the process but not part of the working set,
//! and are not counted. A checkout that would exceed the budget fails with
//! [`BudgetExceeded`] — the paper's "out of memory", now enforced uniformly
//! for *all four* solvers instead of only the block solver's column caches.
//!
//! Reuse is capacity-based best-fit, bounded: a pooled buffer serves any
//! shape whose element count fits within 2× of the request (so a small
//! checkout never hogs — or hides — a much larger buffer; tracked bytes are
//! the buffer's real capacity on reuse). After the first iteration a
//! solver's loop runs with zero new allocations (observable via
//! [`Workspace::misses`], which tests use to assert the arena does not grow
//! across iterations).

use crate::linalg::dense::Mat;
use crate::util::membudget::{BudgetExceeded, MemBudget, Tracked};
use std::cell::{Cell, RefCell};
use std::ops::{Deref, DerefMut};

/// Pool of reusable `f64` buffers with budget accounting.
///
/// Not `Sync`: one workspace belongs to one solver invocation thread (the
/// data-parallel helpers operate on disjoint slices *inside* checked-out
/// buffers and never touch the pool).
pub struct Workspace {
    budget: MemBudget,
    pool: RefCell<Vec<Vec<f64>>>,
    /// Sum of pooled (idle) capacities, bounded by [`Self::idle_allowance`].
    pooled_bytes: Cell<usize>,
    hits: Cell<usize>,
    misses: Cell<usize>,
}

/// Hard cap on pooled buffer count — the solvers hold ≲10 distinct
/// concurrent buffers, so this never binds in practice; it backstops
/// pathological size churn.
const POOL_MAX_BUFFERS: usize = 32;

impl Workspace {
    pub fn new(budget: MemBudget) -> Workspace {
        Workspace {
            budget,
            pool: RefCell::new(Vec::new()),
            pooled_bytes: Cell::new(0),
            hits: Cell::new(0),
            misses: Cell::new(0),
        }
    }

    /// Idle pooled capacity the arena may hold beyond live checkouts: a
    /// quarter of the budget. Buffers returned past this allowance are
    /// freed, so resident memory cannot creep arbitrarily past the limit
    /// through size-churned pool entries.
    fn idle_allowance(&self) -> usize {
        self.budget.limit() / 4
    }

    pub fn budget(&self) -> &MemBudget {
        &self.budget
    }

    /// Checkouts served from the pool (no allocation).
    pub fn hits(&self) -> usize {
        self.hits.get()
    }

    /// Checkouts that had to allocate a fresh buffer. Stable across solver
    /// iterations once the working set is warm.
    pub fn misses(&self) -> usize {
        self.misses.get()
    }

    /// Buffers currently idle in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.borrow().len()
    }

    /// Check out a zeroed `rows × cols` matrix. Tracks `rows·cols·8` bytes
    /// against the budget until the guard drops.
    pub fn mat(&self, rows: usize, cols: usize) -> Result<WsMat<'_>, BudgetExceeded> {
        let (buf, track) = self.take_buf(rows * cols)?;
        Ok(WsMat {
            ws: self,
            mat: Some(Mat::from_rows(rows, cols, buf)),
            _track: track,
        })
    }

    /// Check out a zeroed length-`len` vector.
    pub fn vec(&self, len: usize) -> Result<WsVec<'_>, BudgetExceeded> {
        let (buf, track) = self.take_buf(len)?;
        Ok(WsVec {
            ws: self,
            v: Some(buf),
            _track: track,
        })
    }

    fn take_buf(&self, need: usize) -> Result<(Vec<f64>, Tracked), BudgetExceeded> {
        let f = std::mem::size_of::<f64>();
        let mut pool = self.pool.borrow_mut();
        // Best fit: the smallest pooled buffer whose capacity suffices, but
        // never one more than twice the request — a small checkout must not
        // hog (and hide) a much larger buffer, so tracked bytes stay within
        // 2× of real resident capacity.
        let mut best: Option<(usize, usize)> = None;
        for (k, b) in pool.iter().enumerate() {
            let cap = b.capacity();
            if cap >= need && cap <= 2 * need.max(1) {
                match best {
                    Some((_, bc)) if bc <= cap => {}
                    _ => best = Some((k, cap)),
                }
            }
        }
        if let Some((k, cap)) = best {
            // Track the buffer's real capacity, not just the request. If the
            // candidate's extra capacity no longer fits the remaining budget,
            // fall through to an exact-size allocation instead of failing —
            // a tight budget must reject the *request*, not the pool's shape.
            if let Ok(track) = self.budget.track(cap * f) {
                self.hits.set(self.hits.get() + 1);
                let mut buf = pool.swap_remove(k);
                self.pooled_bytes
                    .set(self.pooled_bytes.get().saturating_sub(cap * f));
                buf.clear();
                buf.resize(need, 0.0);
                return Ok((buf, track));
            }
        }
        // Register before allocating so an over-budget checkout fails
        // cleanly.
        let track = self.budget.track(need * f)?;
        self.misses.set(self.misses.get() + 1);
        Ok((vec![0.0; need], track))
    }

    fn give_back(&self, buf: Vec<f64>) {
        let bytes = buf.capacity() * std::mem::size_of::<f64>();
        let mut pool = self.pool.borrow_mut();
        if pool.len() >= POOL_MAX_BUFFERS
            || self.pooled_bytes.get().saturating_add(bytes) > self.idle_allowance()
        {
            return; // free it: hoarding idle capacity past the allowance
                    // would let resident memory creep beyond the budget
        }
        self.pooled_bytes.set(self.pooled_bytes.get() + bytes);
        pool.push(buf);
    }
}

/// RAII guard for a checked-out matrix; derefs to [`Mat`]. On drop the
/// backing buffer returns to the pool and its bytes leave the budget.
pub struct WsMat<'ws> {
    ws: &'ws Workspace,
    mat: Option<Mat>,
    _track: Tracked,
}

impl Deref for WsMat<'_> {
    type Target = Mat;
    #[inline]
    fn deref(&self) -> &Mat {
        self.mat.as_ref().expect("live checkout")
    }
}

impl DerefMut for WsMat<'_> {
    #[inline]
    fn deref_mut(&mut self) -> &mut Mat {
        self.mat.as_mut().expect("live checkout")
    }
}

impl Drop for WsMat<'_> {
    fn drop(&mut self) {
        if let Some(m) = self.mat.take() {
            self.ws.give_back(m.into_data());
        }
    }
}

/// RAII guard for a checked-out vector; derefs to `[f64]`.
pub struct WsVec<'ws> {
    ws: &'ws Workspace,
    v: Option<Vec<f64>>,
    _track: Tracked,
}

impl Deref for WsVec<'_> {
    type Target = Vec<f64>;
    #[inline]
    fn deref(&self) -> &Vec<f64> {
        self.v.as_ref().expect("live checkout")
    }
}

impl DerefMut for WsVec<'_> {
    #[inline]
    fn deref_mut(&mut self) -> &mut Vec<f64> {
        self.v.as_mut().expect("live checkout")
    }
}

impl Drop for WsVec<'_> {
    fn drop(&mut self) {
        if let Some(v) = self.v.take() {
            self.ws.give_back(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_checkin_reuses_buffers() {
        let ws = Workspace::new(MemBudget::unlimited());
        for it in 0..5 {
            let mut m = ws.mat(8, 8).unwrap();
            assert_eq!((m.rows(), m.cols()), (8, 8));
            // Always zeroed, even when the buffer is recycled.
            assert!(m.data().iter().all(|&x| x == 0.0), "iteration {it}");
            m[(3, 4)] = 1.5;
        }
        assert_eq!(ws.misses(), 1, "arena grew across iterations");
        assert_eq!(ws.hits(), 4);
        assert_eq!(ws.pooled(), 1);
    }

    #[test]
    fn capacity_based_reuse_across_shapes() {
        let budget = MemBudget::unlimited();
        let ws = Workspace::new(budget.clone());
        drop(ws.mat(4, 16).unwrap());
        // Different shape, same element count: served from the pool, and the
        // reused buffer's full capacity is what gets tracked.
        let m = ws.mat(8, 8).unwrap();
        assert_eq!(budget.live(), 64 * 8);
        drop(m);
        // A much smaller request must NOT hog the 64-element buffer
        // (capacity > 2× request): it allocates its own.
        drop(ws.mat(5, 5).unwrap());
        assert_eq!(ws.misses(), 2);
        assert_eq!(ws.hits(), 1);
        // A near-fit request (36 ≤ 64 ≤ 72) reuses it.
        drop(ws.mat(6, 6).unwrap());
        assert_eq!(ws.hits(), 2);
    }

    #[test]
    fn oversized_checkout_fails_budget() {
        let budget = MemBudget::new(1000);
        let ws = Workspace::new(budget.clone());
        assert!(ws.mat(100, 100).is_err(), "80000 bytes must exceed 1000");
        let m = ws.mat(10, 10).unwrap(); // 800 bytes
        assert_eq!(budget.live(), 800);
        // A second concurrent checkout would exceed the limit.
        assert!(ws.vec(100).is_err());
        drop(m);
        assert_eq!(budget.live(), 0);
        assert_eq!(budget.peak(), 800);
        // After checkin the bytes are free again.
        assert!(ws.vec(100).is_ok());
    }

    #[test]
    fn concurrent_checkouts_all_counted() {
        let budget = MemBudget::unlimited();
        let ws = Workspace::new(budget.clone());
        let a = ws.mat(4, 4).unwrap();
        let b = ws.mat(3, 3).unwrap();
        let c = ws.vec(10).unwrap();
        assert_eq!(budget.live(), (16 + 9 + 10) * 8);
        drop((a, b, c));
        assert_eq!(budget.live(), 0);
        assert_eq!(budget.peak(), (16 + 9 + 10) * 8);
        assert_eq!(ws.pooled(), 3);
    }

    #[test]
    fn vec_guard_derefs_mutably() {
        let ws = Workspace::new(MemBudget::unlimited());
        let mut v = ws.vec(5).unwrap();
        v[2] = 7.0;
        assert_eq!(v.len(), 5);
        assert_eq!(v[2], 7.0);
    }
}
