//! **Algorithm 1 — Alternating Newton Coordinate Descent** (paper §3).
//!
//! Per outer iteration:
//! 1. screen the active sets `S_Λ`, `S_Θ` from the gradients (Eq. 3);
//! 2. find a generalized Newton direction `D_Λ` by coordinate descent on the
//!    l1-regularized quadratic model of `g_Θ(Λ)` (Eq. 6), maintaining
//!    `U = Δ_ΛΣ`; update `Λ ← Λ + αD_Λ` with Armijo line search;
//! 3. solve the Θ subproblem (Eq. 7) **directly** by coordinate descent —
//!    it is already quadratic, so no second-order model and no line search —
//!    maintaining `V = ΘΣ`.
//!
//! Versus the Newton CD baseline this never forms `Γ = S_xxΘΣ` (p×q, the
//! O(npq) term) and the per-coordinate costs drop to O(q) for Λ and O(p)
//! for Θ.
//!
//! This is the *non-block* variant: it materializes dense `S_yy`, `Σ`, `Ψ`,
//! `W` (q×q), `S_xx` (p×p) and `Vᵀ` (p×q) — exactly the working set whose
//! growth motivates Algorithm 2.

use super::cd_common::{lambda_cd_pass, theta_cd_pass_direct, trace_grad_dir};
use super::{SolveError, SolveOptions, SolveResult};
use crate::cggm::active::{lambda_active_dense, theta_active_dense};
use crate::cggm::factor::LambdaFactor;
use crate::cggm::linesearch::{lambda_line_search, LineSearchOptions};
use crate::cggm::objective::SmoothParts;
use crate::cggm::{CggmModel, Dataset, Objective};
use crate::gemm::GemmEngine;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::threadpool::Parallelism;
use crate::util::timer::{PhaseProfiler, Stopwatch};

pub fn solve(
    data: &Dataset,
    opts: &SolveOptions,
    engine: &dyn GemmEngine,
) -> Result<SolveResult, SolveError> {
    let (p, q) = (data.p(), data.q());
    let par = opts.parallelism();
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let obj = Objective::new(data, opts.lam_l, opts.lam_t).with_chol(opts.chol);
    let mut model = CggmModel::init(p, q);
    let mut trace = SolveTrace {
        solver: "alt_newton_cd".into(),
        ..Default::default()
    };

    // Dense covariance precomputations — the memory footprint the paper
    // attributes to the non-block methods.
    let syy = prof.time("cov:syy", || data.syy_dense(engine));
    let sxx = prof.time("cov:sxx", || data.sxx_dense(engine));
    let sxy = prof.time("cov:sxy", || data.sxy_dense(engine));
    let sxx_diag: Vec<f64> = (0..p).map(|i| sxx[(i, i)]).collect();

    let mut factor = LambdaFactor::factor(&model.lambda, obj.chol, engine)?;
    let mut rt = data.xtheta_t(&model.theta);
    let mut parts = SmoothParts {
        logdet: factor.logdet(),
        tr_syy_lambda: obj.tr_syy_sparse(&model.lambda),
        tr_sxy_theta: obj.tr_sxy_sparse(&model.theta),
        tr_quad: factor.trace_quad(&rt),
    };
    let mut f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    let mut sigma = prof.time("sigma", || sigma_dense(&factor, engine, &par));
    let ls_opts = LineSearchOptions::default();

    for it in 0..opts.max_iter {
        // ---- screens (gradients at the current iterate) ----
        let psi = prof.time("psi", || obj.psi_dense(&sigma, &rt, engine));
        let gl = prof.time("grad:lambda", || {
            let mut g = syy.clone();
            g.add_scaled(-1.0, &sigma);
            g.add_scaled(-1.0, &psi);
            g
        });
        let gt = prof.time("grad:theta", || obj.grad_theta_dense(&sigma, &rt, engine));
        let (active_l, stats_l) = lambda_active_dense(&gl, &model.lambda, opts.lam_l);
        let (active_t, stats_t) = theta_active_dense(&gt, &model.theta, opts.lam_t);
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = model.lambda.l1_norm() + model.theta.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f,
            active_lambda: full_count(&active_l),
            active_theta: active_t.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }

        // ---- Λ step: CD for the Newton direction, then line search ----
        let mut delta = SpRowMat::zeros(q, q);
        let mut w = Mat::zeros(q, q);
        prof.time("cd:lambda", || {
            for _ in 0..opts.inner_sweeps {
                lambda_cd_pass(
                    &active_l, &syy, &sigma, &psi, &model.lambda, &mut delta, &mut w,
                    opts.lam_l, None,
                );
            }
        });
        let tr_gd = trace_grad_dir(&gl, &delta);
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &delta);
        let delta_armijo = tr_gd + opts.lam_l * (lpd.l1_norm() - model.lambda.l1_norm());
        if delta_armijo < -1e-14 {
            let res = prof.time("linesearch", || {
                lambda_line_search(
                    &obj,
                    &model.lambda,
                    &delta,
                    &rt,
                    f,
                    &parts,
                    delta_armijo,
                    model.theta.l1_norm(),
                    engine,
                    &ls_opts,
                )
            })?;
            model.lambda.add_scaled(res.alpha, &delta);
            model.lambda.prune(0.0);
            factor = res.factor;
            parts = res.parts;
            // (f is recomputed after the Θ phase below.)
            sigma = prof.time("sigma", || sigma_dense(&factor, engine, &par));
        }

        // ---- Θ step: direct CD on the quadratic subproblem ----
        let mut vt = prof.time("vt", || theta_sigma_t(&model.theta, &sigma));
        prof.time("cd:theta", || {
            for _ in 0..opts.inner_sweeps {
                theta_cd_pass_direct(
                    &active_t,
                    &sxx,
                    &sxx_diag,
                    &sxy,
                    &sigma,
                    &mut model.theta,
                    &mut vt,
                    opts.lam_t,
                );
            }
        });
        model.theta.prune(0.0);
        rt = data.xtheta_t(&model.theta);
        parts.tr_sxy_theta = obj.tr_sxy_sparse(&model.theta);
        parts.tr_quad = prof.time("trace_quad", || factor.trace_quad(&rt));
        f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    Ok(SolveResult { model, trace })
}

/// Σ = Λ⁻¹ dense. With a sparse factor, solve per column in parallel
/// (writing column c into row c — Σ is symmetric).
pub(crate) fn sigma_dense(
    factor: &LambdaFactor,
    engine: &dyn GemmEngine,
    par: &Parallelism,
) -> Mat {
    match factor {
        LambdaFactor::Dense(f) => f.inverse(engine),
        LambdaFactor::Sparse(f) => {
            let q = f.n();
            let mut out = Mat::zeros(q, q);
            par.parallel_chunks_mut(out.data_mut(), q, |c, row| {
                let mut e = vec![0.0; q];
                e[c] = 1.0;
                let x = f.solve(&e);
                row.copy_from_slice(&x);
            });
            out.symmetrize();
            out
        }
    }
}

/// (ΘΣ)ᵀ = ΣΘᵀ as a q×p matrix (`vt.row(j)` = column j of V = ΘΣ).
pub(crate) fn theta_sigma_t(theta: &SpRowMat, sigma: &Mat) -> Mat {
    let (p, q) = (theta.rows(), theta.cols());
    // V = Θ·Σ row-wise (contiguous axpys), then transpose.
    let mut v = Mat::zeros(p, q);
    for i in 0..p {
        let row = theta.row(i);
        if row.is_empty() {
            continue;
        }
        let vrow = v.row_mut(i);
        for &(t, val) in row {
            crate::linalg::dense::axpy(val, sigma.row(t), vrow);
        }
    }
    v.transposed()
}

/// Active-set size counting both triangles (what the paper's Fig. 2c plots).
pub(crate) fn full_count(active_upper: &[(usize, usize)]) -> usize {
    active_upper
        .iter()
        .map(|&(i, j)| if i == j { 1 } else { 2 })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;

    #[test]
    fn solves_tiny_chain_to_tolerance() {
        let prob = datagen::chain::generate(12, 12, 80, 3);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.15,
            lam_t: 0.15,
            max_iter: 60,
            ..Default::default()
        };
        let res = solve(&prob.data, &opts, &eng).unwrap();
        assert!(res.trace.converged, "did not converge: {:?}", res.trace.stopping_ratio());
        // Objective decreased monotonically.
        let fs: Vec<f64> = res.trace.records.iter().map(|r| r.f).collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-9, "f increased at {k}: {fs:?}");
        }
        // Estimated Λ recovers chain-ish structure (diagonal positive).
        for i in 0..12 {
            assert!(res.model.lambda.get(i, i) > 0.0);
        }
    }

    #[test]
    fn sigma_dense_paths_agree() {
        let prob = datagen::chain::generate(6, 6, 30, 1);
        let eng = NativeGemm::new(1);
        let fd = LambdaFactor::factor(
            &prob.truth.lambda,
            crate::cggm::CholKind::Dense,
            &eng,
        )
        .unwrap();
        let fs = LambdaFactor::factor(
            &prob.truth.lambda,
            crate::cggm::CholKind::SparseRcm,
            &eng,
        )
        .unwrap();
        let par = Parallelism::new(2);
        let sd = sigma_dense(&fd, &eng, &par);
        let ss = sigma_dense(&fs, &eng, &par);
        assert!(sd.max_abs_diff(&ss) < 1e-8);
    }
}
