//! **Algorithm 1 — Alternating Newton Coordinate Descent** (paper §3).
//!
//! Per outer iteration:
//! 1. screen the active sets `S_Λ`, `S_Θ` from the gradients (Eq. 3);
//! 2. find a generalized Newton direction `D_Λ` by coordinate descent on the
//!    l1-regularized quadratic model of `g_Θ(Λ)` (Eq. 6), maintaining
//!    `U = Δ_ΛΣ`; update `Λ ← Λ + αD_Λ` with Armijo line search;
//! 3. solve the Θ subproblem (Eq. 7) **directly** by coordinate descent —
//!    it is already quadratic, so no second-order model and no line search —
//!    maintaining `V = ΘΣ`.
//!
//! Versus the Newton CD baseline this never forms `Γ = S_xxΘΣ` (p×q, the
//! O(npq) term) and the per-coordinate costs drop to O(q) for Λ and O(p)
//! for Θ.
//!
//! This is the *non-block* variant: it holds dense `S_yy`, `Σ`, `Ψ`, `W`
//! (q×q), `S_xx` (p×p) and `Vᵀ` (p×q) — exactly the working set whose
//! growth motivates Algorithm 2. The statistics come cached from the
//! [`SolverContext`]; every per-iteration buffer is checked out of its
//! workspace arena, so the loop performs no allocations and the budget's
//! `peak()` reports the true working set.

use super::cd_common::{
    lambda_cd_pass, lambda_cd_pass_colored, theta_cd_pass_direct, theta_cd_pass_direct_colored,
    trace_grad_dir, ColoredScratch,
};
use super::{SolveError, SolveOptions, SolveResult, SolverContext};
use crate::cggm::active::{
    lambda_active_dense, lambda_active_within, theta_active_dense, theta_active_within,
};
use crate::cggm::factor::{FactorRepr, LambdaFactor};
use crate::cggm::linesearch::{lambda_line_search, LineSearchOptions};
use crate::cggm::objective::SmoothParts;
use crate::cggm::{CggmModel, Objective};
use crate::gemm::GemmEngine;
use crate::graph::coloring::ConflictSpace;
use crate::linalg::dense::Mat;
use crate::linalg::sparse::SpRowMat;
use crate::metrics::{IterRecord, SolveTrace};
use crate::util::threadpool::Parallelism;
use crate::util::timer::{PhaseProfiler, Stopwatch};

pub fn solve(
    ctx: &SolverContext,
    opts: &SolveOptions,
    warm: Option<&CggmModel>,
) -> Result<SolveResult, SolveError> {
    let data = ctx.data();
    let engine = ctx.engine();
    let ws = ctx.workspace();
    let par = ctx.par();
    let (p, q, n) = (data.p(), data.q(), data.n());
    let prof = PhaseProfiler::new();
    let sw = Stopwatch::start();
    let obj = Objective::new(data, opts.lam_l, opts.lam_t)
        .with_chol(opts.chol)
        .with_budget(ctx.budget().clone());
    let mut model = warm.cloned().unwrap_or_else(|| CggmModel::init(p, q));
    let mut trace = SolveTrace {
        solver: "alt_newton_cd".into(),
        ..Default::default()
    };

    // Cached covariance statistics — computed once per context, so λ-path
    // sweeps and repeated fits pay the Gram cost a single time.
    let syy = prof.time("cov:syy", || ctx.syy())?;
    let sxx = prof.time("cov:sxx", || ctx.sxx())?;
    let sxy = prof.time("cov:sxy", || ctx.sxy())?;
    let sxx_diag = ctx.sxx_diag();

    let mut factor = obj.factor_lambda(&model.lambda, engine)?;
    let mut rt = ws.mat(q, n)?;
    data.xtheta_t_into(&model.theta, &mut rt);
    let mut parts = SmoothParts {
        logdet: factor.logdet(),
        tr_syy_lambda: obj.tr_syy_sparse(&model.lambda),
        tr_sxy_theta: obj.tr_sxy_sparse(&model.theta),
        tr_quad: factor.trace_quad(&rt),
    };
    let mut f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    let mut sigma = ws.mat(q, q)?;
    prof.time("sigma", || sigma_dense_into(&factor, engine, par, ws, &mut sigma))?;
    let ls_opts = LineSearchOptions::default();

    // Path-level strong-rule restriction: when set, screening (and hence CD)
    // only ever touches the allowed coordinates, and the Θ screen evaluates
    // per-coordinate gradients from the shared Σ·R̃ᵀ panel instead of the
    // dense O(npq) GEMM.
    let screen = opts.screen.as_deref();

    // Colored parallel CD (`--cd-threads > 1`): conflict-free color classes
    // from the context's churn-gated coloring cache.
    let cd_par = opts.cd_parallelism();
    let mut cd_scratch = ColoredScratch::default();

    for it in 0..opts.max_iter {
        // ---- screens (gradients at the current iterate) ----
        let mut psi = ws.mat(q, q)?;
        let (active_t, stats_t) = {
            // One Σ·rt panel feeds both Ψ and ∇_Θ (no second O(q²n) GEMM).
            let mut sr = ws.mat(q, n)?;
            prof.time("psi", || obj.psi_into(&sigma, &rt, engine, &mut sr, &mut psi));
            match screen {
                Some(set) => prof.time("grad:theta", || {
                    theta_active_within(
                        |i, j| obj.grad_theta_entry(sxy, &sr, i, j),
                        &model.theta,
                        opts.lam_t,
                        &set.theta,
                    )
                }),
                None => {
                    let mut gt = ws.mat(p, q)?;
                    prof.time("grad:theta", || {
                        obj.grad_theta_from_sr(sxy, &sr, engine, &mut gt)
                    });
                    theta_active_dense(&gt, &model.theta, opts.lam_t)
                }
            }
        };
        let mut gl = ws.mat(q, q)?;
        prof.time("grad:lambda", || {
            gl.copy_from(syy);
            gl.add_scaled(-1.0, &sigma);
            gl.add_scaled(-1.0, &psi);
        });
        let (active_l, stats_l) = match screen {
            Some(set) => lambda_active_within(&gl, &model.lambda, opts.lam_l, &set.lambda),
            None => lambda_active_dense(&gl, &model.lambda, opts.lam_l),
        };
        trace.coords_screened += match screen {
            Some(set) => set.len(),
            None => q * (q + 1) / 2 + p * q,
        };
        let subgrad = stats_l.subgrad_l1 + stats_t.subgrad_l1;
        let param_l1 = model.lambda.l1_norm() + model.theta.l1_norm();
        trace.push(IterRecord {
            iter: it,
            time: sw.seconds(),
            f,
            active_lambda: full_count(&active_l),
            active_theta: active_t.len(),
            subgrad,
            param_l1,
        });
        if subgrad <= opts.tol * param_l1 {
            trace.converged = true;
            break;
        }
        if opts.out_of_time(sw.seconds()) {
            break;
        }
        if opts.cancel.is_cancelled() {
            return Err(SolveError::Cancelled);
        }
        trace.cd_updates += opts.inner_sweeps * (active_l.len() + active_t.len());

        // ---- Λ step: CD for the Newton direction, then line search ----
        let mut delta = SpRowMat::zeros(q, q);
        let mut w = ws.mat(q, q)?;
        prof.time("cd:lambda", || -> Result<(), SolveError> {
            if opts.colored_cd() {
                let mut colorings = ctx.coloring_caches();
                let classes = colorings.lambda.classes_for(
                    &active_l,
                    ConflictSpace::Symmetric(q),
                    opts.recluster_churn,
                    ctx.budget(),
                )?;
                for _ in 0..opts.inner_sweeps {
                    lambda_cd_pass_colored(
                        classes,
                        syy,
                        &sigma,
                        &psi,
                        &model.lambda,
                        &mut delta,
                        &mut w,
                        opts.lam_l,
                        None,
                        &cd_par,
                        &mut cd_scratch,
                    );
                }
            } else {
                for _ in 0..opts.inner_sweeps {
                    lambda_cd_pass(
                        &active_l, syy, &sigma, &psi, &model.lambda, &mut delta, &mut w,
                        opts.lam_l, None,
                    );
                }
            }
            Ok(())
        })?;
        let tr_gd = trace_grad_dir(&gl, &delta);
        let mut lpd = model.lambda.clone();
        lpd.add_scaled(1.0, &delta);
        let delta_armijo = tr_gd + opts.lam_l * (lpd.l1_norm() - model.lambda.l1_norm());
        if delta_armijo < -1e-14 {
            let res = prof.time("linesearch", || {
                lambda_line_search(
                    &obj,
                    &model.lambda,
                    &delta,
                    &rt,
                    f,
                    &parts,
                    delta_armijo,
                    model.theta.l1_norm(),
                    engine,
                    &ls_opts,
                )
            })?;
            model.lambda.add_scaled(res.alpha, &delta);
            model.lambda.prune(0.0);
            factor = res.factor;
            parts = res.parts;
            // (f is recomputed after the Θ phase below.)
            prof.time("sigma", || sigma_dense_into(&factor, engine, par, ws, &mut sigma))?;
        }

        // ---- Θ step: direct CD on the quadratic subproblem ----
        let mut vt = ws.mat(q, p)?;
        {
            let mut v = ws.mat(p, q)?;
            prof.time("vt", || theta_sigma_t_into(&model.theta, &sigma, &mut v, &mut vt));
        }
        prof.time("cd:theta", || -> Result<(), SolveError> {
            if opts.colored_cd() {
                let mut colorings = ctx.coloring_caches();
                let classes = colorings.theta.classes_for(
                    &active_t,
                    ConflictSpace::Bipartite(p, q),
                    opts.recluster_churn,
                    ctx.budget(),
                )?;
                for _ in 0..opts.inner_sweeps {
                    theta_cd_pass_direct_colored(
                        classes,
                        sxx,
                        sxx_diag,
                        sxy,
                        &sigma,
                        &mut model.theta,
                        &mut vt,
                        opts.lam_t,
                        &cd_par,
                        &mut cd_scratch,
                    );
                }
            } else {
                for _ in 0..opts.inner_sweeps {
                    theta_cd_pass_direct(
                        &active_t,
                        sxx,
                        sxx_diag,
                        sxy,
                        &sigma,
                        &mut model.theta,
                        &mut vt,
                        opts.lam_t,
                    );
                }
            }
            Ok(())
        })?;
        model.theta.prune(0.0);
        data.xtheta_t_into(&model.theta, &mut rt);
        parts.tr_sxy_theta = obj.tr_sxy_sparse(&model.theta);
        parts.tr_quad = prof.time("trace_quad", || factor.trace_quad(&rt));
        f = parts.g() + model.penalty(opts.lam_l, opts.lam_t);
    }

    trace.total_seconds = sw.seconds();
    trace.phases = prof
        .report()
        .into_iter()
        .map(|(n, s, c)| (n.to_string(), s, c))
        .collect();
    Ok(SolveResult { model, trace })
}

/// Σ = Λ⁻¹ dense, into a preallocated q×q buffer; the dense path's
/// triangular scratch comes from the workspace arena (budget-visible, no
/// allocation). Both branches are column-parallel under `par`: the sparse
/// factor solves per column (writing column c into row c — Σ is symmetric),
/// and the dense factor's TRSM phase runs band-parallel
/// ([`crate::linalg::chol_dense::DenseChol::inverse_into_scratch_par`]).
pub(crate) fn sigma_dense_into(
    factor: &LambdaFactor,
    engine: &dyn GemmEngine,
    par: &Parallelism,
    ws: &super::workspace::Workspace,
    out: &mut Mat,
) -> Result<(), SolveError> {
    match factor.repr() {
        FactorRepr::Dense(f) => {
            let n = f.n();
            let mut w = ws.mat(n, n)?;
            f.inverse_into_scratch_par(engine, par, &mut w, out);
        }
        FactorRepr::Sparse(f) => {
            let q = f.n();
            debug_assert_eq!((out.rows(), out.cols()), (q, q));
            par.parallel_chunks_mut(out.data_mut(), q, |c, row| {
                let mut e = vec![0.0; q];
                e[c] = 1.0;
                let x = f.solve(&e);
                row.copy_from_slice(&x);
            });
            out.symmetrize();
        }
    }
    Ok(())
}

/// Allocating wrapper over [`sigma_dense_into`] (tests, one-off callers).
pub(crate) fn sigma_dense(
    factor: &LambdaFactor,
    engine: &dyn GemmEngine,
    par: &Parallelism,
) -> Mat {
    let q = match factor.repr() {
        FactorRepr::Dense(f) => f.n(),
        FactorRepr::Sparse(f) => f.n(),
    };
    let ws = super::workspace::Workspace::new(crate::util::membudget::MemBudget::unlimited());
    let mut out = Mat::zeros(q, q);
    sigma_dense_into(factor, engine, par, &ws, &mut out).expect("unlimited budget");
    out
}

/// (ΘΣ)ᵀ = ΣΘᵀ as a q×p matrix (`vt.row(j)` = column j of V = ΘΣ), using a
/// caller-provided p×q scratch `v` — no allocation.
pub(crate) fn theta_sigma_t_into(theta: &SpRowMat, sigma: &Mat, v: &mut Mat, vt: &mut Mat) {
    let (p, q) = (theta.rows(), theta.cols());
    debug_assert_eq!((v.rows(), v.cols()), (p, q));
    debug_assert_eq!((vt.rows(), vt.cols()), (q, p));
    // V = Θ·Σ row-wise (contiguous axpys), then transpose.
    v.fill(0.0);
    for i in 0..p {
        let row = theta.row(i);
        if row.is_empty() {
            continue;
        }
        let vrow = v.row_mut(i);
        for &(t, val) in row {
            crate::linalg::dense::axpy(val, sigma.row(t), vrow);
        }
    }
    v.transpose_into(vt);
}

/// Active-set size counting both triangles (what the paper's Fig. 2c plots).
pub(crate) fn full_count(active_upper: &[(usize, usize)]) -> usize {
    active_upper
        .iter()
        .map(|&(i, j)| if i == j { 1 } else { 2 })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::gemm::native::NativeGemm;
    use crate::solvers::solve_in_context;
    use crate::solvers::SolverKind;

    #[test]
    fn solves_tiny_chain_to_tolerance() {
        let prob = datagen::chain::generate(12, 12, 80, 3);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.15,
            lam_t: 0.15,
            max_iter: 60,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let res = solve(&ctx, &opts, None).unwrap();
        assert!(res.trace.converged, "did not converge: {:?}", res.trace.stopping_ratio());
        // Objective decreased monotonically.
        let fs: Vec<f64> = res.trace.records.iter().map(|r| r.f).collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-9, "f increased at {k}: {fs:?}");
        }
        // Estimated Λ recovers chain-ish structure (diagonal positive).
        for i in 0..12 {
            assert!(res.model.lambda.get(i, i) > 0.0);
        }
    }

    #[test]
    fn workspace_arena_does_not_grow_across_iterations() {
        let prob = datagen::chain::generate(14, 14, 70, 5);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.1,
            lam_t: 0.1,
            max_iter: 40,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let res = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
        let iters = res.trace.records.len();
        assert!(iters >= 3, "need several iterations to exercise reuse");
        let ws = ctx.workspace();
        // First iteration seeds the pool (≤ 9 distinct concurrent buffers);
        // every later iteration must be served from it.
        assert!(
            ws.misses() <= 9,
            "arena misses ({}) grew with iterations ({iters})",
            ws.misses()
        );
        assert!(ws.hits() > ws.misses(), "expected pool reuse after warmup");
        // All buffers returned: nothing live beyond the cached statistics.
        let stats_bytes = 8 * (14 * 14 * 2 + 14 * 14); // syy + sxx + sxy
        assert_eq!(ctx.budget().live(), stats_bytes);
    }

    #[test]
    fn warm_start_from_own_solution_converges_immediately() {
        let prob = datagen::chain::generate(10, 10, 60, 9);
        let eng = NativeGemm::new(1);
        let opts = SolveOptions {
            lam_l: 0.2,
            lam_t: 0.2,
            max_iter: 50,
            ..Default::default()
        };
        let ctx = SolverContext::new(&prob.data, &opts, &eng);
        let cold = solve(&ctx, &opts, None).unwrap();
        assert!(cold.trace.converged);
        let warm = solve(&ctx, &opts, Some(&cold.model)).unwrap();
        assert!(warm.trace.converged);
        assert_eq!(
            warm.trace.records.len(),
            1,
            "restarting at the optimum must converge at the first screen"
        );
    }

    #[test]
    fn sigma_dense_paths_agree() {
        let prob = datagen::chain::generate(6, 6, 30, 1);
        let eng = NativeGemm::new(1);
        let fd = LambdaFactor::factor(
            &prob.truth.lambda,
            crate::cggm::CholKind::Dense,
            &eng,
        )
        .unwrap();
        let fs = LambdaFactor::factor(
            &prob.truth.lambda,
            crate::cggm::CholKind::SparseRcm,
            &eng,
        )
        .unwrap();
        let par = Parallelism::new(2);
        let sd = sigma_dense(&fd, &eng, &par);
        let ss = sigma_dense(&fs, &eng, &par);
        assert!(sd.max_abs_diff(&ss) < 1e-8);
    }
}
