//! Micro-benchmark harness (criterion substitute, DESIGN.md S19).
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness = false`):
//! warmup runs, timed iterations, robust statistics (median + MAD), and
//! criterion-style one-line reports plus CSV rows for EXPERIMENTS.md.

use crate::util::json::Json;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Seconds per iteration.
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    /// Optional work units (e.g. flops) per iteration for rate reporting.
    pub work: Option<f64>,
}

impl BenchStats {
    /// Work rate per second (e.g. FLOP/s when `work` is flops).
    pub fn rate(&self) -> Option<f64> {
        self.work.map(|w| w / self.median)
    }

    pub fn report_line(&self) -> String {
        let rate = match self.rate() {
            Some(r) if r >= 1e9 => format!("  {:8.2} G/s", r / 1e9),
            Some(r) if r >= 1e6 => format!("  {:8.2} M/s", r / 1e6),
            Some(r) => format!("  {r:8.0} /s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ± {:<10} [{} .. {}]{}",
            self.name,
            fmt_time(self.median),
            fmt_time(self.mad),
            fmt_time(self.min),
            fmt_time(self.max),
            rate
        )
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.9},{:.9},{:.9},{:.9},{:.9}\n",
            self.name, self.iters, self.median, self.mean, self.min, self.max, self.mad
        )
    }

    /// Machine-readable row for the `BENCH_*.json` trajectory files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("median_s", Json::num(self.median)),
            ("mean_s", Json::num(self.mean)),
            ("min_s", Json::num(self.min)),
            ("max_s", Json::num(self.max)),
            ("mad_s", Json::num(self.mad)),
            ("rate", self.rate().map(Json::num).unwrap_or(Json::Null)),
        ])
    }
}

/// Where a bench writes its machine-readable trajectory (`BENCH_<TAG>.json`).
/// Benches run with `rust/` as the working directory; `CGGM_BENCH_DIR`
/// overrides the destination (CI points it at the artifact staging dir).
pub fn bench_json_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::var("CGGM_BENCH_DIR").unwrap_or_else(|_| ".".into());
    std::path::Path::new(&dir).join(format!("BENCH_{tag}.json"))
}

/// Write a bench trajectory document, reporting the destination. These
/// files are the committed perf baseline future PRs regress against — see
/// docs/PERF.md for the schema.
pub fn write_bench_json(tag: &str, doc: &Json) {
    let path = bench_json_path(tag);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-> {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// One benchmark case builder.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    work: Option<f64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench {
            name: name.into(),
            warmup: 2,
            iters: 10,
            work: None,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    /// Work units per iteration (for rate reporting), e.g. 2·m·n·k flops.
    pub fn work(mut self, units: f64) -> Self {
        self.work = Some(units);
        self
    }

    /// Run the benchmark; `f` is invoked warmup+iters times.
    pub fn run<T>(self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let mut devs: Vec<f64> = sorted.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: self.name,
            iters: self.iters,
            median,
            mean: samples.iter().sum::<f64>() / samples.len() as f64,
            min: sorted[0],
            max: *sorted.last().unwrap(),
            mad: devs[devs.len() / 2],
            work: self.work,
        };
        println!("{}", stats.report_line());
        stats
    }
}

/// A collection of benchmark rows, written to `results/bench_<name>.csv`.
pub struct BenchSet {
    pub name: String,
    pub rows: Vec<BenchStats>,
}

impl BenchSet {
    pub fn new(name: impl Into<String>) -> BenchSet {
        let name = name.into();
        println!("== bench: {name} ==");
        BenchSet {
            name,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, stats: BenchStats) {
        self.rows.push(stats);
    }

    /// Write CSV to `results/bench_<name>.csv`.
    pub fn finish(self) {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        let mut csv = String::from("name,iters,median,mean,min,max,mad\n");
        for r in &self.rows {
            csv.push_str(&r.csv_row());
        }
        let path = dir.join(format!("bench_{}.csv", self.name));
        if let Err(e) = std::fs::write(&path, csv) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("-> {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let stats = Bench::new("noop")
            .warmup(1)
            .iters(5)
            .work(100.0)
            .run(|| std::hint::black_box(1 + 1));
        assert_eq!(stats.iters, 5);
        assert!(stats.median >= 0.0);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
        assert!(stats.rate().unwrap() > 0.0);
        assert!(stats.report_line().contains("noop"));
        assert!(stats.csv_row().starts_with("noop,5,"));
        let j = stats.to_json();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("noop"));
        assert!(j.get("median_s").and_then(|v| v.as_f64()).is_some());
        assert!(j.get("rate").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }

    #[test]
    fn bench_json_path_honors_env_dir() {
        // (Reads the var only; other tests run in parallel so we don't set it.)
        let p = bench_json_path("SELFTEST");
        assert!(p.to_string_lossy().ends_with("BENCH_SELFTEST.json"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).contains("us"));
    }
}
