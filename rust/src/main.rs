//! `cggm` — CLI for the sparse conditional Gaussian graphical model
//! estimation framework (McCarter & Kim 2015 reproduction).
//!
//! Subcommands:
//! - `gen`   generate a synthetic workload and save it;
//! - `fit`   estimate a CGGM (solver/engine/budget configurable);
//! - `path`  fit a warm-started λ regularization path (strong-rule screened);
//! - `cv`    K-fold cross-validated λ selection + full-data refit;
//! - `serve` long-lived JSONL job server with warm per-dataset contexts;
//! - `batch` execute a manifest of serve jobs through the same engine;
//! - `exp`   regenerate a paper table/figure (`--list` shows all);
//! - `cal`   calibrate λ for a workload;
//! - `info`  environment + artifact status.

use cggm::coordinator::{self, RunConfig};
use cggm::datagen;
use cggm::experiments;
use cggm::gemm::GemmEngine;
use cggm::metrics::f1_edges_sym;
use cggm::runtime;
use cggm::serve::{self, ServeEngine};
use cggm::util::cli::Args;
use cggm::util::membudget::fmt_bytes;
use std::path::PathBuf;

const BOOL_FLAGS: &[&str] = &[
    "list",
    "verbose",
    "calibrate",
    "no-clustering",
    "trace",
    "quick",
    "cold",
    "one-se",
    "gemm-autotune",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = raw[0].clone();
    let args = Args::parse(&raw[1..], BOOL_FLAGS);
    let code = match cmd.as_str() {
        "gen" => cmd_gen(&args),
        "fit" => cmd_fit(&args),
        "path" => cmd_path(&args),
        "cv" => cmd_cv(&args),
        "serve" => cmd_serve(&args),
        "batch" => cmd_batch(&args),
        "exp" => cmd_exp(&args),
        "cal" => cmd_cal(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        r#"cggm — sparse CGGM estimation (McCarter & Kim 2015)

USAGE: cggm <command> [flags]

COMMANDS
  gen   --workload chain|cluster|genomic --p N --q N --n N [--seed S] --out FILE
        [--storage disk [--shard-cols N]]
        (--storage disk writes the sharded CGGMPAN1 panel format that
         fit/path/cv/serve can bind out-of-core instead of loading resident)
  fit   [--config FILE] [--workload ...|--data FILE] --solver newton|alt|bcd|prox
        [--lambda X | --calibrate] [--mem-budget 512MB] [--threads T]
        [--cd-threads T] [--engine native|xla|pallas [--tile 128|256]] [--trace]
        [--stat-mode dense|tiled [--stat-tile N]]
        [--storage mem|disk [--panel-rows N] [--panel-cache 64MB]]
        [--gemm-blocks mc,kc,nc | --gemm-autotune]
        (--threads drives column/GEMM parallelism; --cd-threads > 1 switches
         the CD sweeps to colored conflict-free parallel passes;
         --stat-mode tiled makes bcd compute S_xx/S_xy Gram tiles on demand
         through a budget-bound LRU cache with disk spill;
         --storage disk streams a sharded --data file through a budget-tracked
         panel cache instead of holding X/Y resident — see docs/PERF.md)
  path  [--config FILE] [--workload ...|--data FILE] --solver newton|alt|bcd|prox
        [--path-points N] [--path-min-ratio R] [--screen full|strong] [--cold]
        [--checkpoint FILE | --resume FILE] [--recluster-churn X]
        [--time-limit S] ...
        (warm-started λ path: stats computed once, each point seeds the next
         and carries its active set forward via the sequential strong rule;
         --time-limit budgets the whole sweep; --cold disables warm starts;
         --checkpoint streams each fitted point to a JSONL file and --resume
         warm-restarts an interrupted sweep from its last valid point)
  cv    [--config FILE] [--workload ...|--data FILE] --solver ... --folds K
        [--cv-threads T] [--path-points N] [--path-min-ratio R]
        [--screen full|strong] [--one-se] [--seed S]
        [--checkpoint FILE | --resume FILE] ...
        (K-fold CV over the λ path: per-fold contexts, folds in parallel,
         held-out NLL scoring, winning λ refit on the full data; --one-se
         selects the sparsest λ within one standard error of the best;
         --checkpoint streams fold progress to a JSONL file and --resume
         carries completed folds over verbatim)
  serve [--config FILE] [--max-jobs N] [--serve-budget 1GB]
        [--socket PATH] [--threads T] [--cd-threads T] ...
        (long-lived JSONL job server: one request object per line on stdio
         — or PATH with --socket, serving concurrent connections — against
         named warm datasets; ops: load, fit, path, cv, append, refit,
         stat, evict, cancel, save, export, shutdown; path/cv take
         "stream":true for per-point progress lines; append buffers new
         samples and refit folds them into the sliding window with
         incremental Gram updates + a warm re-solve; see docs/SERVING.md)
  batch FILE [--out-file FILE] [--max-jobs N] [--serve-budget 1GB] ...
        (execute a JSON manifest of serve jobs through the same engine;
         responses printed as JSONL, ordered by job id)
  exp   <id>|all [--list] [--scale F] [--sizes a,b,c] [--lambda X] ...
  cal   --workload ... --p N --q N --n N
  info

Engines: native (blocked Rust GEMM), xla / pallas (AOT artifacts via PJRT;
requires `make artifacts`)."#
    );
}

/// Engine from the layered config (defaults ← config file ← CLI flags):
/// `--engine`, `--threads`, `--tile`, plus the native block-size policy
/// (`--gemm-blocks mc,kc,nc` beats `--gemm-autotune` when both are given).
fn make_engine(cfg: &RunConfig) -> std::sync::Arc<dyn GemmEngine> {
    let blocks = match (cfg.gemm_blocks, cfg.gemm_autotune) {
        (Some((mc, kc, nc)), _) => runtime::GemmBlocks::Explicit(mc, kc, nc),
        (None, true) => runtime::GemmBlocks::Autotune,
        (None, false) => runtime::GemmBlocks::Default,
    };
    match runtime::make_engine_with(&cfg.engine, cfg.threads, cfg.tile, blocks) {
        Ok(e) => e,
        Err(e) => {
            eprintln!(
                "engine '{}' unavailable ({e}); falling back to native",
                cfg.engine
            );
            std::sync::Arc::new(cggm::gemm::native::NativeGemm::new(cfg.threads))
        }
    }
}

fn load_config(args: &Args) -> RunConfig {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::from_file(path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => RunConfig::default(),
    };
    cfg.apply_args(args);
    cfg
}

fn cmd_gen(args: &Args) -> i32 {
    let cfg = load_config(args);
    let out = args.get_str("out", "dataset.bin");
    eprintln!(
        "generating {:?} workload p={} q={} n={} seed={} ({} format)",
        cfg.workload,
        cfg.p,
        cfg.q,
        cfg.n,
        cfg.seed,
        if cfg.storage == "disk" {
            "sharded panel"
        } else {
            "dense"
        }
    );
    let prob = coordinator::generate_problem(cfg.workload, cfg.p, cfg.q, cfg.n, cfg.seed);
    // `--storage disk` writes the sharded CGGMPAN1 panel format so the file
    // can later be bound out-of-core (`fit --data FILE --storage disk`).
    let write = if cfg.storage == "disk" {
        let shard = args.get_usize("shard-cols", 1024).max(1);
        coordinator::save_dataset_sharded(&prob.data, &PathBuf::from(&out), shard)
    } else {
        coordinator::save_dataset(&prob.data, &PathBuf::from(&out))
    };
    match write {
        Ok(()) => {
            eprintln!(
                "wrote {out} (truth: nnz(L*)={} nnz(T*)={})",
                prob.truth.lambda_nnz(),
                prob.truth.theta_nnz()
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Problem from `--data FILE` (unknown truth) or the configured generator.
fn load_problem(args: &Args, cfg: &RunConfig) -> Result<datagen::Problem, i32> {
    match args.opt("data") {
        Some(path) => {
            let data = match coordinator::open_dataset(
                &PathBuf::from(path),
                &cfg.storage,
                cfg.panel_rows,
                cfg.panel_cache,
            ) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("cannot load {path}: {e}");
                    return Err(1);
                }
            };
            if data.is_disk() {
                eprintln!(
                    "dataset {path} bound disk-backed (panel rows {}, cache {})",
                    cfg.panel_rows,
                    fmt_bytes(cfg.panel_cache)
                );
            }
            let (p, q) = (data.p(), data.q());
            Ok(datagen::Problem {
                truth: cggm::cggm::CggmModel::init(p, q),
                data,
            })
        }
        None => Ok(coordinator::generate_problem(
            cfg.workload,
            cfg.p,
            cfg.q,
            cfg.n,
            cfg.seed,
        )),
    }
}

fn cmd_fit(args: &Args) -> i32 {
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let prob = match load_problem(args, &cfg) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let mut opts = cfg.solve_options();
    if cfg.calibrate {
        eprintln!("calibrating lambda ...");
        let (l, t) = coordinator::calibrate_lambda(&prob, engine.as_ref(), &opts, 5);
        eprintln!("  lambda_l = {l:.4}, lambda_t = {t:.4}");
        opts.lam_l = l;
        opts.lam_t = t;
    }
    let trace_path = args
        .flag("trace")
        .then(|| PathBuf::from(&cfg.out_dir).join(format!("trace_{}.csv", cfg.solver.name())));
    eprintln!(
        "fitting {} (engine={}, p={}, q={}, n={}, lambda=({:.3},{:.3}))",
        cfg.solver.name(),
        engine.name(),
        prob.p(),
        prob.q(),
        prob.n(),
        opts.lam_l,
        opts.lam_t
    );
    match coordinator::run_fit(
        cfg.solver,
        &prob,
        &opts,
        engine.as_ref(),
        trace_path.as_deref(),
    ) {
        Ok((sum, res)) => {
            println!("{}", sum.to_json().to_string_pretty());
            if args.flag("verbose") {
                eprintln!("phase breakdown:");
                for (phase, secs, calls) in &res.trace.phases {
                    eprintln!("  {phase:<24} {secs:>9.2}s ({calls} calls)");
                }
                let f1 = f1_edges_sym(&res.model.lambda, &prob.truth.lambda);
                eprintln!(
                    "structure recovery: precision={:.3} recall={:.3} F1={:.3}",
                    f1.precision, f1.recall, f1.f1
                );
            }
            0
        }
        Err(e) => {
            eprintln!("solve failed: {e}");
            1
        }
    }
}

fn cmd_path(args: &Args) -> i32 {
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let prob = match load_problem(args, &cfg) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let opts = cfg.solve_options();
    let mut popts = cfg.path_options(!args.flag("cold"));
    if let Some(ck) = args.opt("resume") {
        popts.checkpoint = Some(PathBuf::from(ck));
        popts.resume = true;
    }
    if args.opt("lambda").is_some()
        || args.opt("lambda-l").is_some()
        || args.opt("lambda-t").is_some()
        || args.flag("calibrate")
    {
        eprintln!(
            "note: `path` auto-generates its λ grid from the data's λ_max; \
             --lambda/--lambda-l/--lambda-t/--calibrate are ignored \
             (tune --path-points / --path-min-ratio instead)"
        );
    }
    eprintln!(
        "λ path: {} (engine={}, p={}, q={}, n={}, {} points, min ratio {}, {}, screen={})",
        cfg.solver.name(),
        engine.name(),
        prob.p(),
        prob.q(),
        prob.n(),
        popts.points,
        popts.min_ratio,
        if popts.warm_start { "warm starts" } else { "cold starts" },
        popts.screen.name(),
    );
    match coordinator::fit_path(cfg.solver, &prob.data, &opts, &popts, engine.as_ref()) {
        Ok(path) => {
            if path.resumed_points > 0 {
                eprintln!(
                    "resumed from checkpoint: {} of {} points carried over, {} refitted",
                    path.resumed_points,
                    path.points.len(),
                    path.points.len().saturating_sub(path.resumed_points),
                );
            }
            println!("{}", path.to_json().to_string_pretty());
            let dir = PathBuf::from(&cfg.out_dir);
            let _ = std::fs::create_dir_all(&dir);
            let csv = dir.join(format!("path_{}.csv", cfg.solver.name()));
            match std::fs::write(&csv, path.to_csv()) {
                Ok(()) => eprintln!("-> {}", csv.display()),
                Err(e) => eprintln!("warning: cannot write {}: {e}", csv.display()),
            }
            0
        }
        Err(e) => {
            eprintln!("path failed: {e}");
            1
        }
    }
}

fn cmd_cv(args: &Args) -> i32 {
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let prob = match load_problem(args, &cfg) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let opts = cfg.solve_options();
    let popts = cfg.path_options(!args.flag("cold"));
    let mut cvo = cfg.cv_options();
    if let Some(ck) = args.opt("resume") {
        cvo.checkpoint = Some(PathBuf::from(ck));
        cvo.resume = true;
    }
    eprintln!(
        "cv: {} (engine={}, p={}, q={}, n={}, {} folds × {} points, \
         screen={}, {} fold threads)",
        cfg.solver.name(),
        engine.name(),
        prob.p(),
        prob.q(),
        prob.n(),
        cvo.folds,
        popts.points,
        popts.screen.name(),
        cvo.fold_threads,
    );
    match coordinator::cross_validate(cfg.solver, &prob.data, &opts, &popts, &cvo, engine.as_ref())
    {
        Ok(res) => {
            println!("{}", res.to_json().to_string_pretty());
            if res.resumed_folds > 0 {
                eprintln!(
                    "resumed from checkpoint: {} of {} folds carried over",
                    res.resumed_folds, res.folds
                );
            }
            eprintln!(
                "selected lambda=({:.4},{:.4}) at point {} of {}{} \
                 (mean held-out NLL {:.4})",
                res.best_lambda.0,
                res.best_lambda.1,
                res.selected + 1,
                res.points.len(),
                if res.selected != res.best {
                    format!(" [one-SE; argmin at point {}]", res.best + 1)
                } else {
                    String::new()
                },
                res.points[res.selected].mean_nll,
            );
            let dir = PathBuf::from(&cfg.out_dir);
            let _ = std::fs::create_dir_all(&dir);
            let csv = dir.join(format!("cv_{}.csv", cfg.solver.name()));
            match std::fs::write(&csv, res.to_csv()) {
                Ok(()) => eprintln!("-> {}", csv.display()),
                Err(e) => eprintln!("warning: cannot write {}: {e}", csv.display()),
            }
            0
        }
        Err(e) => {
            eprintln!("cv failed: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let budget = cfg
        .serve_budget
        .map(fmt_bytes)
        .unwrap_or_else(|| "unlimited".into());
    eprintln!(
        "cggm serve: {} worker(s), budget {}, engine {}, defaults solver={} \
         threads={} cd_threads={}",
        cfg.serve_max_jobs.max(1),
        budget,
        engine.name(),
        cfg.solver.name(),
        cfg.threads,
        cfg.cd_threads,
    );
    let socket = cfg.serve_socket.clone();
    let srv = ServeEngine::new(cfg, engine);
    let result = match socket {
        Some(path) => {
            eprintln!(
                "listening on unix socket {path} (one JSON request per line; \
                 concurrent connections)"
            );
            serve_on_socket(&srv, &path)
        }
        None => {
            eprintln!("serving on stdio (one JSON request per line; EOF or \
                       {{\"op\":\"shutdown\"}} ends the session)");
            let stdin = std::io::stdin();
            let mut stdout = std::io::stdout();
            serve::serve_connection(&srv, stdin.lock(), &mut stdout)
        }
    };
    srv.join();
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve transport error: {e}");
            1
        }
    }
}

#[cfg(unix)]
fn serve_on_socket(srv: &ServeEngine, path: &str) -> std::io::Result<()> {
    serve::serve_unix(srv, std::path::Path::new(path))
}

#[cfg(not(unix))]
fn serve_on_socket(_srv: &ServeEngine, _path: &str) -> std::io::Result<()> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "--socket requires unix domain sockets; use stdio mode",
    ))
}

fn cmd_batch(args: &Args) -> i32 {
    let Some(file) = args.positional.first() else {
        eprintln!("usage: cggm batch FILE [--out-file FILE] (see docs/SERVING.md)");
        return 2;
    };
    let manifest = match runtime::manifest::JobManifest::load(&PathBuf::from(file)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot read manifest {file}: {e}");
            return 1;
        }
    };
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    eprintln!(
        "cggm batch: {} job(s) from {file}, {} worker(s)",
        manifest.jobs().len(),
        cfg.serve_max_jobs.max(1),
    );
    let out = args.opt("out-file").map(PathBuf::from);
    let srv = ServeEngine::new(cfg, engine);
    let outcome = serve::run_batch(&srv, &manifest);
    srv.join();
    let jsonl = outcome.to_jsonl();
    print!("{jsonl}");
    if let Some(path) = out {
        if let Err(e) = std::fs::write(&path, &jsonl) {
            eprintln!("warning: cannot write {}: {e}", path.display());
        } else {
            eprintln!("-> {}", path.display());
        }
    }
    if outcome.failures > 0 {
        eprintln!(
            "{} of {} job(s) failed",
            outcome.failures,
            outcome.responses.len()
        );
        1
    } else {
        0
    }
}

fn cmd_exp(args: &Args) -> i32 {
    if args.flag("list") || args.positional.is_empty() {
        println!("available experiments:");
        for (id, desc) in experiments::registry() {
            println!("  {id:<8} {desc}");
        }
        return 0;
    }
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let mut code = 0;
    for id in &args.positional {
        let ids: Vec<String> = if id == "all" {
            experiments::registry()
                .iter()
                .map(|(i, _)| i.to_string())
                .collect()
        } else {
            vec![id.clone()]
        };
        for id in ids {
            if let Err(e) = experiments::run(&id, args, engine.as_ref()) {
                eprintln!("experiment {id} failed: {e}");
                code = 1;
            }
        }
    }
    code
}

fn cmd_cal(args: &Args) -> i32 {
    let cfg = load_config(args);
    let engine = make_engine(&cfg);
    let prob = coordinator::generate_problem(cfg.workload, cfg.p, cfg.q, cfg.n, cfg.seed);
    let opts = cfg.solve_options();
    let (l, t) = coordinator::calibrate_lambda(&prob, engine.as_ref(), &opts, 6);
    println!(
        "{}",
        cggm::util::json::Json::obj(vec![
            ("lambda_l", cggm::util::json::Json::num(l)),
            ("lambda_t", cggm::util::json::Json::num(t)),
        ])
        .to_string()
    );
    0
}

fn cmd_info(args: &Args) -> i32 {
    println!("cggm {}", env!("CARGO_PKG_VERSION"));
    let names: Vec<&str> = cggm::solvers::SolverKind::all()
        .iter()
        .map(|k| k.name())
        .collect();
    println!("solvers: {}", names.join(", "));
    let dir = runtime::artifact_dir();
    match cggm::runtime::manifest::Manifest::load(&dir.join("manifest.json")) {
        Ok(m) => {
            println!(
                "artifacts: {} entries in {}",
                m.entries.len(),
                dir.display()
            );
            if args.flag("verbose") {
                for (name, e) in &m.entries {
                    println!("  {name:<28} kind={:<10} file={}", e.kind, e.file);
                }
            }
            match runtime::XlaGemm::load_default(&dir) {
                Ok(_) => println!("PJRT engine: OK (cpu)"),
                Err(e) => println!("PJRT engine: unavailable ({e})"),
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`); native engine only"),
    }
    0
}
