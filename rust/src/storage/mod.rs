//! Out-of-core dataset storage: disk-backed X/Y feature panels behind a
//! budget-tracked LRU cache.
//!
//! The paper's million-dimensional claims assume the *statistics* are the
//! memory bottleneck, but at p + q ~ 10⁶ even the raw data panels X (p×n)
//! and Y (q×n) exceed RAM. This module keeps them on disk in a sharded,
//! checksummed binary **panel format** and serves feature-row panels through
//! a [`PanelCache`] that registers every resident panel against the shared
//! [`MemBudget`] via RAII [`Tracked`] handles — the same infallible-
//! degradation design as `cggm::tiles::TileStore`: when neither the cache
//! capacity nor the budget admits a panel, the read still succeeds as a
//! bounded *transient* allocation that is dropped as soon as the caller is
//! done with it.
//!
//! # File format (`CGGMPAN1`, version 1)
//!
//! A panel file is a 48-byte global header followed by any number of
//! shards, each a 64-byte shard header plus a row-major f64 little-endian
//! payload:
//!
//! ```text
//! global:  magic "CGGMPAN1" | version u32 | flags u32 | p u64 | q u64
//!          | reserved u64 | fnv1a64(bytes 0..40) u64
//! shard:   magic "CGGMSHRD" | space u32 (0=X, 1=Y) | reserved u32
//!          | row_start u64 | row_end u64 | col_start u64 | col_end u64
//!          | payload_bytes u64 | fnv1a64(bytes 0..56) u64
//! payload: (row_end-row_start) × (col_end-col_start) f64 LE, row-major
//! ```
//!
//! Version-1 constraints, checked by [`read_meta`] with the same
//! bounded-before-allocation discipline as the checkpoint loaders: every
//! shard spans the full feature-row range of its space; per space, shard
//! column ranges are contiguous from 0 (so shards are an append log of
//! sample blocks); dimensions and shard counts are capped *before* any
//! payload-sized allocation; header checksums must match; a payload that
//! runs past end-of-file is a structured "torn tail" error, mirroring a
//! crashed writer.
//!
//! Eviction of old samples (the sliding window) is a *logical* offset kept
//! in memory only — the file is append-only and the evict offset is a
//! session-local view, exactly like a reader's cursor into a log.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::linalg::dense::Mat;
use crate::util::membudget::{MemBudget, Tracked};

/// Global file header magic.
pub const GLOBAL_MAGIC: [u8; 8] = *b"CGGMPAN1";
/// Per-shard header magic.
pub const SHARD_MAGIC: [u8; 8] = *b"CGGMSHRD";
/// On-disk format version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;
const GLOBAL_HEADER_LEN: u64 = 48;
const SHARD_HEADER_LEN: u64 = 64;
/// Feature dimensions are bounded before any allocation sized by them.
pub const DIM_CAP: u64 = 1 << 24;
/// Shard-table length is bounded before the table is built.
pub const SHARD_CAP: usize = 1 << 20;
/// Sample count is bounded so payload arithmetic cannot overflow u64.
pub const COL_CAP: u64 = 1 << 32;

/// Default feature rows per cached panel.
pub const DEFAULT_PANEL_ROWS: usize = 256;
/// Default panel-cache capacity in bytes (64 MB).
pub const DEFAULT_PANEL_CACHE: usize = 64 << 20;

/// Which data matrix a shard or panel belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Space {
    /// Inputs X (p features).
    X,
    /// Outputs Y (q features).
    Y,
}

impl Space {
    #[inline]
    fn tag(self) -> u8 {
        match self {
            Space::X => 0,
            Space::Y => 1,
        }
    }
    fn from_u32(v: u32) -> Option<Space> {
        match v {
            0 => Some(Space::X),
            1 => Some(Space::Y),
            _ => None,
        }
    }
}

/// FNV-1a 64-bit — the header checksum. Not cryptographic; it catches
/// torn writes and bit rot, which is all a local panel file needs.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Structured panel-file validation failure. Every variant converts to
/// `io::ErrorKind::InvalidData` so callers that speak `io::Result` get a
/// descriptive message without a second error type in their signatures.
#[derive(Debug, thiserror::Error)]
pub enum StorageError {
    #[error("panel file i/o: {0}")]
    Io(#[from] io::Error),
    #[error("bad panel-file magic")]
    BadMagic,
    #[error("unsupported panel-file version {0}")]
    BadVersion(u32),
    #[error("panel-file header checksum mismatch")]
    BadChecksum,
    #[error("panel-file dimensions out of range (p={p}, q={q}, cap={DIM_CAP})")]
    DimsOutOfRange { p: u64, q: u64 },
    #[error("invalid shard header: {0}")]
    ShardInvalid(&'static str),
    #[error("torn shard tail: {0}")]
    TornTail(&'static str),
    #[error("unbalanced X/Y sample counts (x={x}, y={y})")]
    Unbalanced { x: usize, y: usize },
}

impl From<StorageError> for io::Error {
    fn from(e: StorageError) -> io::Error {
        match e {
            StorageError::Io(io) => io,
            other => io::Error::new(io::ErrorKind::InvalidData, other.to_string()),
        }
    }
}

/// One validated shard: a contiguous block of samples for one space.
#[derive(Clone, Debug)]
pub struct ShardMeta {
    pub space: Space,
    /// Physical sample-column range `[col_start, col_end)`.
    pub col_start: usize,
    pub col_end: usize,
    /// File offset of the payload (just past the shard header).
    pub offset: u64,
}

impl ShardMeta {
    #[inline]
    fn cols(&self) -> usize {
        self.col_end - self.col_start
    }
}

/// The validated header view of a panel file: dimensions, shard table, and
/// where valid data ends (the append point).
#[derive(Clone, Debug)]
pub struct PanelMeta {
    pub p: usize,
    pub q: usize,
    /// Total samples in the file (X and Y agree by construction).
    pub n: usize,
    pub shards: Vec<ShardMeta>,
    /// Offset one past the last valid shard — where an appender writes.
    pub data_end: u64,
}

fn u32_at(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}
fn u64_at(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

/// Parse and validate a panel file's global header and shard table.
///
/// Bounded-before-allocation: dimensions are capped before the shard table
/// is sized, the shard count is capped as it grows, and no payload is read
/// at all — only header bytes. Any structural violation is a typed
/// [`StorageError`]; the only allocations made before full validation are
/// the fixed-size header buffers and the (capped) shard table.
pub fn read_meta<R: Read + Seek>(r: &mut R) -> Result<PanelMeta, StorageError> {
    let file_len = r.seek(SeekFrom::End(0))?;
    r.seek(SeekFrom::Start(0))?;
    if file_len < GLOBAL_HEADER_LEN {
        return Err(StorageError::TornTail("file shorter than global header"));
    }
    let mut gh = [0u8; GLOBAL_HEADER_LEN as usize];
    r.read_exact(&mut gh)?;
    if gh[..8] != GLOBAL_MAGIC {
        return Err(StorageError::BadMagic);
    }
    let version = u32_at(&gh, 8);
    if version != FORMAT_VERSION {
        return Err(StorageError::BadVersion(version));
    }
    if u64_at(&gh, 40) != fnv1a64(&gh[..40]) {
        return Err(StorageError::BadChecksum);
    }
    let (p, q) = (u64_at(&gh, 16), u64_at(&gh, 24));
    if p == 0 || q == 0 || p > DIM_CAP || q > DIM_CAP {
        return Err(StorageError::DimsOutOfRange { p, q });
    }
    let (p, q) = (p as usize, q as usize);

    let mut shards = Vec::new();
    let mut pos = GLOBAL_HEADER_LEN;
    let (mut n_x, mut n_y) = (0u64, 0u64);
    let mut sh = [0u8; SHARD_HEADER_LEN as usize];
    while pos < file_len {
        if file_len - pos < SHARD_HEADER_LEN {
            return Err(StorageError::TornTail("partial shard header at end of file"));
        }
        r.read_exact(&mut sh)?;
        if sh[..8] != SHARD_MAGIC {
            return Err(StorageError::ShardInvalid("bad shard magic"));
        }
        if u64_at(&sh, 56) != fnv1a64(&sh[..56]) {
            return Err(StorageError::BadChecksum);
        }
        let space = Space::from_u32(u32_at(&sh, 8))
            .ok_or(StorageError::ShardInvalid("unknown space tag"))?;
        let dim = match space {
            Space::X => p,
            Space::Y => q,
        } as u64;
        let (row_start, row_end) = (u64_at(&sh, 16), u64_at(&sh, 24));
        if row_start != 0 || row_end != dim {
            return Err(StorageError::ShardInvalid("v1 shards must span the full row range"));
        }
        let (col_start, col_end) = (u64_at(&sh, 32), u64_at(&sh, 40));
        let n_so_far = match space {
            Space::X => n_x,
            Space::Y => n_y,
        };
        if col_start != n_so_far {
            return Err(StorageError::ShardInvalid("non-contiguous shard column range"));
        }
        if col_end <= col_start || col_end > COL_CAP {
            return Err(StorageError::ShardInvalid("empty or oversized shard column range"));
        }
        let want_payload = dim
            .checked_mul(col_end - col_start)
            .and_then(|c| c.checked_mul(8))
            .ok_or(StorageError::ShardInvalid("payload size overflow"))?;
        if u64_at(&sh, 48) != want_payload {
            return Err(StorageError::ShardInvalid("payload size disagrees with shard shape"));
        }
        let payload_at = pos + SHARD_HEADER_LEN;
        let next = payload_at
            .checked_add(want_payload)
            .ok_or(StorageError::ShardInvalid("payload offset overflow"))?;
        if next > file_len {
            return Err(StorageError::TornTail("shard payload runs past end of file"));
        }
        if shards.len() >= SHARD_CAP {
            return Err(StorageError::ShardInvalid("too many shards"));
        }
        shards.push(ShardMeta {
            space,
            col_start: col_start as usize,
            col_end: col_end as usize,
            offset: payload_at,
        });
        match space {
            Space::X => n_x = col_end,
            Space::Y => n_y = col_end,
        }
        pos = next;
        r.seek(SeekFrom::Start(pos))?;
    }
    if n_x != n_y {
        return Err(StorageError::Unbalanced {
            x: n_x as usize,
            y: n_y as usize,
        });
    }
    Ok(PanelMeta {
        p,
        q,
        n: n_x as usize,
        shards,
        data_end: pos,
    })
}

fn global_header(p: usize, q: usize) -> [u8; GLOBAL_HEADER_LEN as usize] {
    let mut h = [0u8; GLOBAL_HEADER_LEN as usize];
    h[..8].copy_from_slice(&GLOBAL_MAGIC);
    h[8..12].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
    // flags [12..16) and reserved [32..40) stay zero.
    h[16..24].copy_from_slice(&(p as u64).to_le_bytes());
    h[24..32].copy_from_slice(&(q as u64).to_le_bytes());
    let ck = fnv1a64(&h[..40]);
    h[40..48].copy_from_slice(&ck.to_le_bytes());
    h
}

fn shard_header(space: Space, rows: usize, col_start: usize, col_end: usize) -> [u8; 64] {
    let mut h = [0u8; SHARD_HEADER_LEN as usize];
    h[..8].copy_from_slice(&SHARD_MAGIC);
    h[8..12].copy_from_slice(&(space.tag() as u32).to_le_bytes());
    h[16..24].copy_from_slice(&0u64.to_le_bytes());
    h[24..32].copy_from_slice(&(rows as u64).to_le_bytes());
    h[32..40].copy_from_slice(&(col_start as u64).to_le_bytes());
    h[40..48].copy_from_slice(&(col_end as u64).to_le_bytes());
    let payload = (rows as u64) * ((col_end - col_start) as u64) * 8;
    h[48..56].copy_from_slice(&payload.to_le_bytes());
    let ck = fnv1a64(&h[..56]);
    h[56..64].copy_from_slice(&ck.to_le_bytes());
    h
}

/// Streaming shard writer: create a panel file and append feature-major
/// sample blocks without ever holding more than one block in memory — the
/// datagen path to paper-scale files.
pub struct PanelWriter {
    w: io::BufWriter<File>,
    p: usize,
    q: usize,
    n: usize,
}

impl PanelWriter {
    /// Create (truncating) `path` for a p×n / q×n dataset built by appends.
    pub fn create(path: &Path, p: usize, q: usize) -> io::Result<PanelWriter> {
        if p == 0 || q == 0 || p as u64 > DIM_CAP || q as u64 > DIM_CAP {
            return Err(StorageError::DimsOutOfRange {
                p: p as u64,
                q: q as u64,
            }
            .into());
        }
        let f = File::create(path)?;
        let mut w = io::BufWriter::new(f);
        w.write_all(&global_header(p, q))?;
        Ok(PanelWriter { w, p, q, n: 0 })
    }

    /// Samples written so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Append one feature-major block (`xt`: p×k, `yt`: q×k) as an X shard
    /// followed by a Y shard.
    pub fn append_block(&mut self, xt: &Mat, yt: &Mat) -> io::Result<()> {
        assert_eq!(xt.rows(), self.p, "X feature count mismatch");
        assert_eq!(yt.rows(), self.q, "Y feature count mismatch");
        assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
        let k = xt.cols();
        if k == 0 {
            return Ok(());
        }
        if (self.n + k) as u64 > COL_CAP {
            return Err(StorageError::ShardInvalid("sample count over cap").into());
        }
        for (space, mat) in [(Space::X, xt), (Space::Y, yt)] {
            self.w
                .write_all(&shard_header(space, mat.rows(), self.n, self.n + k))?;
            for &v in mat.data() {
                self.w.write_all(&v.to_le_bytes())?;
            }
        }
        self.n += k;
        Ok(())
    }

    /// Flush and durably sync the file.
    pub fn finish(mut self) -> io::Result<()> {
        self.w.flush()?;
        self.w.get_ref().sync_all()
    }
}

/// Write a fully resident dataset as a panel file, sharded every
/// `shard_cols` samples (the shard size trades append granularity against
/// per-shard header overhead and read fan-in; see docs/PERF.md).
pub fn write_panel_dataset(path: &Path, xt: &Mat, yt: &Mat, shard_cols: usize) -> io::Result<()> {
    assert_eq!(xt.cols(), yt.cols(), "sample count mismatch");
    let shard_cols = shard_cols.max(1);
    let mut w = PanelWriter::create(path, xt.rows(), yt.rows())?;
    let n = xt.cols();
    let mut c = 0;
    while c < n {
        let k = shard_cols.min(n - c);
        let xs = Mat::from_fn(xt.rows(), k, |i, j| xt[(i, c + j)]);
        let ys = Mat::from_fn(yt.rows(), k, |i, j| yt[(i, c + j)]);
        w.append_block(&xs, &ys)?;
        c += k;
    }
    w.finish()
}

/// Panel-cache traffic counters. `transient` counts reads that could not be
/// admitted (cache full of hotter panels, or budget exhausted) and were
/// served as unregistered short-lived allocations instead — the degradation
/// path, never a failure path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelStats {
    pub reads: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub transient: u64,
}

/// A resident (or transient) feature-row panel: rows
/// `[row_start, row_start + mat.rows())` of one space, all live samples.
/// The budget registration lives *inside* the Arc, so a panel evicted from
/// the cache while a solver still holds it stays counted until the last
/// reference drops.
pub struct Panel {
    pub row_start: usize,
    pub mat: Mat,
    _track: Option<Tracked>,
}

struct CacheSlot {
    panel: Arc<Panel>,
    last_used: u64,
    bytes: usize,
}

struct CacheState {
    panel_rows: usize,
    cache_bytes: usize,
    budget: MemBudget,
    map: HashMap<(u8, usize), CacheSlot>,
    resident_bytes: usize,
    clock: u64,
    stats: PanelStats,
}

impl CacheState {
    fn clear(&mut self) {
        self.map.clear();
        self.resident_bytes = 0;
    }

    /// Drop the least-recently-used resident panel. False when empty.
    fn evict_lru(&mut self) -> bool {
        let victim = self
            .map
            .iter()
            .min_by_key(|(_, s)| s.last_used)
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                let slot = self.map.remove(&k).unwrap();
                self.resident_bytes -= slot.bytes;
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }
}

struct DiskState {
    file: File,
    writable: bool,
    p: usize,
    q: usize,
    shards: Vec<ShardMeta>,
    /// Physical samples in the file.
    n_total: usize,
    /// Logical evict offset: live samples are physical columns
    /// `[evict, n_total)`. In-memory only — the file is append-only.
    evict: usize,
    /// Where the next appended shard goes.
    data_end: u64,
    cache: CacheState,
}

/// A disk-backed dataset source. `Clone` shares the underlying file, shard
/// table, evict offset, and panel cache — window mutations (`append`,
/// `evict_oldest`) are visible through every clone, which is exactly what
/// the serving refit path wants.
#[derive(Clone)]
pub struct DiskSource {
    path: PathBuf,
    inner: Arc<Mutex<DiskState>>,
}

impl std::fmt::Debug for DiskSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.inner.lock().unwrap();
        f.debug_struct("DiskSource")
            .field("path", &self.path)
            .field("p", &st.p)
            .field("q", &st.q)
            .field("n", &(st.n_total - st.evict))
            .finish()
    }
}

impl DiskSource {
    /// Open and validate a panel file. The file is opened read-write when
    /// possible (so the sliding window can append); a read-only filesystem
    /// degrades to a read-only source whose appends fail.
    pub fn open(path: &Path, panel_rows: usize, cache_bytes: usize) -> io::Result<DiskSource> {
        let (file, writable) = match OpenOptions::new().read(true).write(true).open(path) {
            Ok(f) => (f, true),
            Err(_) => (File::open(path)?, false),
        };
        let meta = {
            let mut r = &file;
            read_meta(&mut r)?
        };
        Ok(DiskSource {
            path: path.to_path_buf(),
            inner: Arc::new(Mutex::new(DiskState {
                file,
                writable,
                p: meta.p,
                q: meta.q,
                shards: meta.shards,
                n_total: meta.n,
                evict: 0,
                data_end: meta.data_end,
                cache: CacheState {
                    panel_rows: panel_rows.max(1),
                    cache_bytes,
                    budget: MemBudget::unlimited(),
                    map: HashMap::new(),
                    resident_bytes: 0,
                    clock: 0,
                    stats: PanelStats::default(),
                },
            })),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn p(&self) -> usize {
        self.inner.lock().unwrap().p
    }
    pub fn q(&self) -> usize {
        self.inner.lock().unwrap().q
    }
    /// Live (non-evicted) sample count.
    pub fn n(&self) -> usize {
        let st = self.inner.lock().unwrap();
        st.n_total - st.evict
    }
    pub fn panel_rows(&self) -> usize {
        self.inner.lock().unwrap().cache.panel_rows
    }
    pub fn cache_bytes(&self) -> usize {
        self.inner.lock().unwrap().cache.cache_bytes
    }
    pub fn stats(&self) -> PanelStats {
        self.inner.lock().unwrap().cache.stats
    }

    /// Feature rows of `space` (p for X, q for Y).
    pub fn dim(&self, space: Space) -> usize {
        let st = self.inner.lock().unwrap();
        match space {
            Space::X => st.p,
            Space::Y => st.q,
        }
    }

    /// Number of fixed-granularity panels covering `space`.
    pub fn n_panels(&self, space: Space) -> usize {
        let st = self.inner.lock().unwrap();
        let dim = match space {
            Space::X => st.p,
            Space::Y => st.q,
        };
        (dim + st.cache.panel_rows - 1) / st.cache.panel_rows
    }

    /// Small bookkeeping overhead — the panels themselves self-register
    /// against the bound budget, so callers must not double-count them.
    pub fn overhead_bytes(&self) -> usize {
        let st = self.inner.lock().unwrap();
        st.shards.len() * std::mem::size_of::<ShardMeta>() + std::mem::size_of::<DiskState>()
    }

    /// Rebind the budget panels register against. A no-op when `budget`
    /// is already the bound one; otherwise the cache is cleared so every
    /// resident panel re-admits under the new budget.
    pub fn bind_budget(&self, budget: &MemBudget) {
        let mut st = self.inner.lock().unwrap();
        if budget.same(&st.cache.budget) {
            return;
        }
        st.cache.clear();
        st.cache.budget = budget.clone();
    }

    /// Fetch the `idx`-th fixed-granularity panel of `space` through the
    /// cache. Infallible degradation: a panel that cannot be admitted is
    /// returned as a transient unregistered allocation.
    pub fn panel(&self, space: Space, idx: usize) -> io::Result<Arc<Panel>> {
        let mut st = self.inner.lock().unwrap();
        st.cache.clock += 1;
        let clock = st.cache.clock;
        st.cache.stats.reads += 1;
        let key = (space.tag(), idx);
        if let Some(slot) = st.cache.map.get_mut(&key) {
            slot.last_used = clock;
            st.cache.stats.hits += 1;
            return Ok(slot.panel.clone());
        }
        st.cache.stats.misses += 1;
        let dim = match space {
            Space::X => st.p,
            Space::Y => st.q,
        };
        let pr = st.cache.panel_rows;
        let row_start = idx * pr;
        assert!(row_start < dim, "panel index out of range");
        let row_end = (row_start + pr).min(dim);
        let n = st.n_total - st.evict;
        let mut mat = Mat::zeros(row_end - row_start, n);
        read_rows_cols(
            &st.file,
            &st.shards,
            space,
            row_start..row_end,
            st.evict..st.n_total,
            &mut mat,
        )?;
        let bytes = mat.bytes() + std::mem::size_of::<Panel>();
        loop {
            if st.cache.resident_bytes + bytes <= st.cache.cache_bytes {
                if let Ok(t) = st.cache.budget.track(bytes) {
                    let panel = Arc::new(Panel {
                        row_start,
                        mat,
                        _track: Some(t),
                    });
                    st.cache.resident_bytes += bytes;
                    st.cache.map.insert(
                        key,
                        CacheSlot {
                            panel: panel.clone(),
                            last_used: clock,
                            bytes,
                        },
                    );
                    return Ok(panel);
                }
            }
            if !st.cache.evict_lru() {
                st.cache.stats.transient += 1;
                return Ok(Arc::new(Panel {
                    row_start,
                    mat,
                    _track: None,
                }));
            }
        }
    }

    /// The panel holding feature row `i` of `space`, plus `i`'s local row.
    pub fn row_panel(&self, space: Space, i: usize) -> io::Result<(Arc<Panel>, usize)> {
        let pr = self.panel_rows();
        let panel = self.panel(space, i / pr)?;
        Ok((panel, i % pr))
    }

    /// Append `k` samples (`xa`: p×k, `ya`: q×k) as a new X/Y shard pair at
    /// the end of the file. Clears the panel cache (every panel's column
    /// extent changed).
    pub fn append(&self, xa: &Mat, ya: &Mat) -> io::Result<()> {
        let mut st = self.inner.lock().unwrap();
        assert_eq!(xa.rows(), st.p, "appended X feature count mismatch");
        assert_eq!(ya.rows(), st.q, "appended Y feature count mismatch");
        assert_eq!(xa.cols(), ya.cols(), "appended sample count mismatch");
        let k = xa.cols();
        if k == 0 {
            return Ok(());
        }
        if !st.writable {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                "panel file opened read-only; cannot append",
            ));
        }
        if (st.n_total + k) as u64 > COL_CAP {
            return Err(StorageError::ShardInvalid("sample count over cap").into());
        }
        let n0 = st.n_total;
        let mut at = st.data_end;
        let mut new_shards = Vec::with_capacity(2);
        for (space, mat) in [(Space::X, xa), (Space::Y, ya)] {
            let hdr = shard_header(space, mat.rows(), n0, n0 + k);
            st.file.write_all_at(&hdr, at)?;
            at += SHARD_HEADER_LEN;
            let mut payload = Vec::with_capacity(mat.data().len() * 8);
            for &v in mat.data() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            st.file.write_all_at(&payload, at)?;
            new_shards.push(ShardMeta {
                space,
                col_start: n0,
                col_end: n0 + k,
                offset: at,
            });
            at += payload.len() as u64;
        }
        st.shards.extend(new_shards);
        st.data_end = at;
        st.n_total += k;
        st.cache.clear();
        Ok(())
    }

    /// Drop the `k` oldest live samples, returning them as feature-major
    /// panels (`xt`: p×k, `yt`: q×k). The read is transient (never cached);
    /// the file itself is untouched — only the logical offset moves.
    pub fn evict_oldest(&self, k: usize) -> io::Result<(Mat, Mat)> {
        let mut st = self.inner.lock().unwrap();
        let k = k.min(st.n_total - st.evict);
        let cols = st.evict..st.evict + k;
        let mut xh = Mat::zeros(st.p, k);
        let mut yh = Mat::zeros(st.q, k);
        read_rows_cols(&st.file, &st.shards, Space::X, 0..st.p, cols.clone(), &mut xh)?;
        read_rows_cols(&st.file, &st.shards, Space::Y, 0..st.q, cols, &mut yh)?;
        st.evict += k;
        st.cache.clear();
        Ok((xh, yh))
    }
}

/// Read feature rows `rows` × physical sample columns `phys_cols` of
/// `space` into `out` (`rows.len() × phys_cols.len()`), gathering across
/// every overlapping shard with positioned reads.
fn read_rows_cols(
    file: &File,
    shards: &[ShardMeta],
    space: Space,
    rows: std::ops::Range<usize>,
    phys_cols: std::ops::Range<usize>,
    out: &mut Mat,
) -> io::Result<()> {
    debug_assert_eq!((out.rows(), out.cols()), (rows.len(), phys_cols.len()));
    let mut scratch = Vec::new();
    for shard in shards.iter().filter(|s| s.space == space) {
        let lo = shard.col_start.max(phys_cols.start);
        let hi = shard.col_end.min(phys_cols.end);
        if lo >= hi {
            continue;
        }
        let seg = hi - lo;
        scratch.resize(seg * 8, 0u8);
        for (r, g) in rows.clone().enumerate() {
            let off = shard.offset + ((g * shard.cols() + (lo - shard.col_start)) as u64) * 8;
            file.read_exact_at(&mut scratch, off)?;
            let dst = &mut out.row_mut(r)[lo - phys_cols.start..hi - phys_cols.start];
            for (d, chunk) in dst.iter_mut().zip(scratch.chunks_exact(8)) {
                *d = f64::from_le_bytes(chunk.try_into().unwrap());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cggm_storage_{}_{}", name, std::process::id()))
    }

    fn random_mats(rng: &mut Rng, p: usize, q: usize, n: usize) -> (Mat, Mat) {
        (
            Mat::from_fn(p, n, |_, _| rng.normal()),
            Mat::from_fn(q, n, |_, _| rng.normal()),
        )
    }

    #[test]
    fn roundtrip_reads_back_exact_values() {
        let mut rng = Rng::new(7);
        let (p, q, n) = (11, 6, 23);
        let (xt, yt) = random_mats(&mut rng, p, q, n);
        let path = tmp("roundtrip.pan");
        write_panel_dataset(&path, &xt, &yt, 5).unwrap();
        let src = DiskSource::open(&path, 4, usize::MAX).unwrap();
        assert_eq!((src.p(), src.q(), src.n()), (p, q, n));
        for space in [Space::X, Space::Y] {
            let want = if space == Space::X { &xt } else { &yt };
            for idx in 0..src.n_panels(space) {
                let panel = src.panel(space, idx).unwrap();
                for r in 0..panel.mat.rows() {
                    assert_eq!(panel.mat.row(r), want.row(panel.row_start + r));
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_hits_evictions_and_transient_degradation() {
        let mut rng = Rng::new(8);
        let (xt, yt) = random_mats(&mut rng, 16, 4, 32);
        let path = tmp("cache.pan");
        write_panel_dataset(&path, &xt, &yt, 32).unwrap();
        // Each X panel is 4×32 f64 ≈ 1KB + struct overhead; cache fits ~2.
        let panel_bytes = 4 * 32 * 8 + std::mem::size_of::<Panel>();
        let src = DiskSource::open(&path, 4, 2 * panel_bytes).unwrap();
        for idx in [0usize, 0, 1, 2, 3, 0] {
            src.panel(Space::X, idx).unwrap();
        }
        let st = src.stats();
        assert_eq!(st.reads, 6);
        assert!(st.hits >= 1, "repeat read of panel 0 should hit");
        assert!(st.evictions >= 1, "capacity 2 over 4 panels must evict");
        assert_eq!(st.transient, 0);

        // A budget too small for even one panel degrades to transient reads.
        let tight = MemBudget::new(16);
        src.bind_budget(&tight);
        src.panel(Space::X, 0).unwrap();
        assert!(src.stats().transient >= 1);
        assert_eq!(tight.live(), 0, "transient panels never stay registered");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn evicted_but_held_panel_stays_budget_registered() {
        let mut rng = Rng::new(9);
        let (xt, yt) = random_mats(&mut rng, 8, 2, 10);
        let path = tmp("held.pan");
        write_panel_dataset(&path, &xt, &yt, 10).unwrap();
        let panel_bytes = 4 * 10 * 8 + std::mem::size_of::<Panel>();
        let src = DiskSource::open(&path, 4, panel_bytes).unwrap();
        let budget = MemBudget::new(usize::MAX);
        src.bind_budget(&budget);
        let held = src.panel(Space::X, 0).unwrap();
        src.panel(Space::X, 1).unwrap(); // evicts panel 0 (capacity 1)
        assert!(src.stats().evictions >= 1);
        assert!(budget.live() >= panel_bytes, "held panel still counted");
        drop(held);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn append_and_evict_slide_the_logical_window() {
        let mut rng = Rng::new(10);
        let (xt, yt) = random_mats(&mut rng, 5, 3, 6);
        let path = tmp("window.pan");
        write_panel_dataset(&path, &xt, &yt, 6).unwrap();
        let src = DiskSource::open(&path, 8, usize::MAX).unwrap();
        let (xa, ya) = random_mats(&mut rng, 5, 3, 2);
        src.append(&xa, &ya).unwrap();
        assert_eq!(src.n(), 8);
        let panel = src.panel(Space::X, 0).unwrap();
        for i in 0..5 {
            assert_eq!(&panel.mat.row(i)[..6], xt.row(i));
            assert_eq!(&panel.mat.row(i)[6..], xa.row(i));
        }
        let (xh, yh) = src.evict_oldest(2).unwrap();
        assert_eq!(src.n(), 6);
        for i in 0..5 {
            assert_eq!(xh.row(i), &xt.row(i)[..2]);
        }
        for j in 0..3 {
            assert_eq!(yh.row(j), &yt.row(j)[..2]);
        }
        let panel = src.panel(Space::Y, 0).unwrap();
        for j in 0..3 {
            assert_eq!(&panel.mat.row(j)[..4], &yt.row(j)[2..]);
        }
        // Reopening sees the appended samples; the evict offset is
        // session-local and resets.
        let re = DiskSource::open(&path, 8, usize::MAX).unwrap();
        assert_eq!(re.n(), 8);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hostile_headers_are_structured_errors() {
        let mut rng = Rng::new(11);
        let (xt, yt) = random_mats(&mut rng, 3, 2, 4);
        let path = tmp("hostile.pan");
        write_panel_dataset(&path, &xt, &yt, 4).unwrap();
        let good = std::fs::read(&path).unwrap();

        let parse = |bytes: &[u8]| read_meta(&mut io::Cursor::new(bytes));

        assert!(matches!(parse(&good), Ok(m) if m.n == 4));
        assert!(matches!(parse(&good[..20]), Err(StorageError::TornTail(_))));
        assert!(matches!(
            parse(&good[..good.len() - 7]),
            Err(StorageError::TornTail(_))
        ));
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(parse(&bad), Err(StorageError::BadMagic)));
        let mut bad = good.clone();
        bad[17] ^= 0x40; // flip a bit of p without fixing the checksum
        assert!(matches!(parse(&bad), Err(StorageError::BadChecksum)));
        // Oversized dims with a *valid* checksum must still be rejected.
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&(DIM_CAP + 1).to_le_bytes());
        let ck = fnv1a64(&bad[..40]);
        bad[40..48].copy_from_slice(&ck.to_le_bytes());
        assert!(matches!(parse(&bad), Err(StorageError::DimsOutOfRange { .. })));
        // Truncating mid-payload is a torn tail.
        assert!(matches!(
            parse(&good[..good.len() - 3 * 4 * 8 + 5]),
            Err(StorageError::TornTail(_))
        ));
        std::fs::remove_file(&path).ok();
    }
}
