//! Evaluation metrics and per-iteration traces backing every figure:
//! suboptimality curves (Figs. 1c, 4a), active-set trajectories (Figs. 2c,
//! 4b), F1 edge recovery (Fig. 5b), and the min-norm-subgradient stopping
//! rule (§5: ‖grad^S f‖₁ < 0.01·(‖Λ‖₁+‖Θ‖₁)).

use crate::linalg::sparse::SpRowMat;
use crate::util::json::Json;

/// Precision/recall/F1 of support recovery.
#[derive(Clone, Copy, Debug, Default)]
pub struct F1 {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub true_positives: usize,
    pub predicted: usize,
    pub actual: usize,
}

/// F1 over the off-diagonal support of symmetric matrices (Λ edge recovery,
/// Fig. 5b). Each undirected edge counted once.
pub fn f1_edges_sym(estimate: &SpRowMat, truth: &SpRowMat) -> F1 {
    let q = truth.rows();
    let mut tp = 0usize;
    let mut pred = 0usize;
    let mut act = 0usize;
    for i in 0..q {
        for &(j, v) in estimate.row(i) {
            if j > i && v != 0.0 {
                pred += 1;
                if truth.get(i, j) != 0.0 {
                    tp += 1;
                }
            }
        }
        act += truth.row(i).iter().filter(|&&(j, v)| j > i && v != 0.0).count();
    }
    build_f1(tp, pred, act)
}

/// F1 over all entries of a (generally rectangular) sparse matrix (Θ).
pub fn f1_entries(estimate: &SpRowMat, truth: &SpRowMat) -> F1 {
    let mut tp = 0usize;
    let mut pred = 0usize;
    let mut act = 0usize;
    for i in 0..truth.rows() {
        pred += estimate.row(i).iter().filter(|e| e.1 != 0.0).count();
        act += truth.row(i).iter().filter(|e| e.1 != 0.0).count();
        for &(j, v) in estimate.row(i) {
            if v != 0.0 && truth.get(i, j) != 0.0 {
                tp += 1;
            }
        }
    }
    build_f1(tp, pred, act)
}

fn build_f1(tp: usize, pred: usize, act: usize) -> F1 {
    let precision = if pred > 0 { tp as f64 / pred as f64 } else { 0.0 };
    let recall = if act > 0 { tp as f64 / act as f64 } else { 0.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    F1 {
        precision,
        recall,
        f1,
        true_positives: tp,
        predicted: pred,
        actual: act,
    }
}

/// One solver iteration record.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// Seconds since solve start.
    pub time: f64,
    /// Objective value f.
    pub f: f64,
    /// |S_Λ| (active-set size, both triangles like the paper's plots).
    pub active_lambda: usize,
    /// |S_Θ|.
    pub active_theta: usize,
    /// ‖grad^S f‖₁.
    pub subgrad: f64,
    /// ‖Λ‖₁ + ‖Θ‖₁ (stopping-rule denominator).
    pub param_l1: f64,
}

/// Full trace of a solve.
#[derive(Clone, Debug, Default)]
pub struct SolveTrace {
    pub records: Vec<IterRecord>,
    /// Phase-time attribution, copied from the solver's profiler.
    pub phases: Vec<(String, f64, u64)>,
    pub converged: bool,
    pub total_seconds: f64,
    pub solver: String,
    /// Coordinates *examined* by active-set screening, summed over outer
    /// iterations: `q(q+1)/2 + pq` per iteration for a full screen, the
    /// screen-set size for a restricted one. The λ-path screening bench's
    /// work metric. Instrumented by the screen-honoring solvers
    /// (`alt_newton_cd`, `newton_cd`, `prox_grad`); the block solver reports
    /// 0, which means "not measured", not "no work".
    pub coords_screened: usize,
    /// Coordinate-descent update visits (active-set size × inner sweeps,
    /// summed over outer iterations; for `prox_grad`, prox coordinates
    /// touched per accepted step). Same instrumentation scope as
    /// `coords_screened`.
    pub cd_updates: usize,
    /// Graph-clustering partition rebuilds performed by the block solver
    /// (`alt_newton_bcd`). The partition is cached in the `SolverContext`
    /// and reused while active-set churn stays under
    /// `SolveOptions::recluster_churn`, so a warm path point typically
    /// reports 0 — the λ-path persistence tests pin this.
    pub reclusterings: usize,
    /// Whether this solve was seeded from a previous solution (λ-path warm
    /// starts, the serve registry's cached models). Set centrally by
    /// `solvers::solve_in_context`, so warm-vs-cold behavior is observable
    /// from the trace JSON without a profiler.
    pub warm_started: bool,
    /// Cached statistics corrected *in place* by an incremental window
    /// update (`SolverContext::update_stats`) over the context's lifetime —
    /// dense Gram matrices, the S_xx diagonal, and resident tiles. Non-zero
    /// means this solve ran on incrementally maintained statistics (a
    /// streaming re-fit) instead of a from-scratch rebuild. Set centrally by
    /// `solvers::solve_in_context`, like `warm_started`.
    pub stat_updates: usize,
    /// Tile-cache activity under `StatMode::Tiled` (all zero for dense-stat
    /// solves): entry reads served from a resident tile / reads that had to
    /// materialize one, LRU evictions and the subset spilled to disk, Gram
    /// tiles actually built by GEMM, and the tile count a full S_xx/S_xy
    /// would need — `tiles_computed < total_tiles` is the observable proof
    /// that screening kept whole tiles untouched.
    pub tile_hits: usize,
    pub tile_misses: usize,
    pub tile_evictions: usize,
    pub tile_spills: usize,
    pub tiles_computed: usize,
    pub total_tiles: usize,
    /// Data-panel cache activity for disk-backed datasets (both zero when
    /// the dataset is resident): total panel fetches through the cache, and
    /// the subset served without touching the panel file.
    pub panel_reads: u64,
    pub panel_cache_hits: u64,
}

impl SolveTrace {
    pub fn push(&mut self, r: IterRecord) {
        self.records.push(r);
    }

    pub fn final_f(&self) -> Option<f64> {
        self.records.last().map(|r| r.f)
    }

    /// Paper's stopping rule on the last record.
    pub fn stopping_ratio(&self) -> Option<f64> {
        self.records.last().map(|r| r.subgrad / r.param_l1.max(1e-300))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solver", Json::str(self.solver.clone())),
            ("converged", Json::Bool(self.converged)),
            ("total_seconds", Json::num(self.total_seconds)),
            ("coords_screened", Json::num(self.coords_screened as f64)),
            ("cd_updates", Json::num(self.cd_updates as f64)),
            ("reclusterings", Json::num(self.reclusterings as f64)),
            ("warm_started", Json::Bool(self.warm_started)),
            ("stat_updates", Json::num(self.stat_updates as f64)),
            ("tile_hits", Json::num(self.tile_hits as f64)),
            ("tile_misses", Json::num(self.tile_misses as f64)),
            ("tile_evictions", Json::num(self.tile_evictions as f64)),
            ("tile_spills", Json::num(self.tile_spills as f64)),
            ("tiles_computed", Json::num(self.tiles_computed as f64)),
            ("total_tiles", Json::num(self.total_tiles as f64)),
            ("panel_reads", Json::num(self.panel_reads as f64)),
            ("panel_cache_hits", Json::num(self.panel_cache_hits as f64)),
            (
                "phases",
                Json::arr(self.phases.iter().map(|(name, secs, calls)| {
                    Json::obj(vec![
                        ("phase", Json::str(name.clone())),
                        ("seconds", Json::num(*secs)),
                        ("calls", Json::num(*calls as f64)),
                    ])
                })),
            ),
            (
                "iters",
                Json::arr(self.records.iter().map(|r| {
                    Json::obj(vec![
                        ("iter", Json::num(r.iter as f64)),
                        ("time", Json::num(r.time)),
                        ("f", Json::num(r.f)),
                        ("active_lambda", Json::num(r.active_lambda as f64)),
                        ("active_theta", Json::num(r.active_theta as f64)),
                        ("subgrad", Json::num(r.subgrad)),
                        ("param_l1", Json::num(r.param_l1)),
                    ])
                })),
            ),
        ])
    }

    /// CSV with one row per iteration (for plotting the figures).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("iter,time,f,active_lambda,active_theta,subgrad,param_l1\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{:.6},{:.10},{},{},{:.8},{:.6}\n",
                r.iter, r.time, r.f, r.active_lambda, r.active_theta, r.subgrad, r.param_l1
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_perfect_and_empty() {
        let mut truth = SpRowMat::zeros(4, 4);
        truth.set_sym(0, 1, 1.0);
        truth.set_sym(2, 3, 1.0);
        let est = truth.clone();
        let f = f1_edges_sym(&est, &truth);
        assert_eq!(f.f1, 1.0);
        assert_eq!(f.true_positives, 2);
        let none = SpRowMat::zeros(4, 4);
        let f0 = f1_edges_sym(&none, &truth);
        assert_eq!(f0.f1, 0.0);
    }

    #[test]
    fn f1_partial() {
        let mut truth = SpRowMat::zeros(4, 4);
        truth.set_sym(0, 1, 1.0);
        truth.set_sym(1, 2, 1.0);
        let mut est = SpRowMat::zeros(4, 4);
        est.set_sym(0, 1, 0.5); // TP
        est.set_sym(0, 3, 0.5); // FP
        let f = f1_edges_sym(&est, &truth);
        assert_eq!(f.precision, 0.5);
        assert_eq!(f.recall, 0.5);
        assert_eq!(f.f1, 0.5);
    }

    #[test]
    fn f1_entries_rectangular() {
        let mut truth = SpRowMat::zeros(3, 2);
        truth.set(0, 0, 1.0);
        truth.set(2, 1, 1.0);
        let mut est = SpRowMat::zeros(3, 2);
        est.set(0, 0, 2.0);
        let f = f1_entries(&est, &truth);
        assert_eq!(f.precision, 1.0);
        assert_eq!(f.recall, 0.5);
    }

    #[test]
    fn trace_json_roundtrips() {
        let mut t = SolveTrace {
            solver: "alt".into(),
            ..Default::default()
        };
        t.push(IterRecord {
            iter: 0,
            time: 0.5,
            f: 12.25,
            active_lambda: 10,
            active_theta: 20,
            subgrad: 1.5,
            param_l1: 30.0,
        });
        t.converged = true;
        t.tiles_computed = 7;
        t.total_tiles = 12;
        t.tile_hits = 100;
        t.stat_updates = 5;
        t.panel_reads = 40;
        t.panel_cache_hits = 33;
        let j = t.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("converged").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("stat_updates").unwrap().as_f64(), Some(5.0));
        assert_eq!(parsed.get("tiles_computed").unwrap().as_f64(), Some(7.0));
        assert_eq!(parsed.get("total_tiles").unwrap().as_f64(), Some(12.0));
        assert_eq!(parsed.get("tile_hits").unwrap().as_f64(), Some(100.0));
        assert_eq!(parsed.get("panel_reads").unwrap().as_f64(), Some(40.0));
        assert_eq!(parsed.get("panel_cache_hits").unwrap().as_f64(), Some(33.0));
        assert_eq!(
            parsed.get("iters").unwrap().as_arr().unwrap()[0]
                .get("f")
                .unwrap()
                .as_f64(),
            Some(12.25)
        );
        assert!(t.to_csv().lines().count() == 2);
        assert!((t.stopping_ratio().unwrap() - 0.05).abs() < 1e-12);
    }
}
