//! `Json::parse` on arbitrary bytes: must never panic, abort, or overflow
//! the stack (depth cap), and anything it *accepts* must round-trip
//! through the writer to an equal value.

#![no_main]

use cggm::util::json::Json;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    if let Ok(v) = Json::parse(text) {
        // Writer output is itself valid JSON parsing back to the same
        // value (modulo the documented non-finite → null lossy case,
        // which the parser can never produce).
        let reparsed = Json::parse(&v.to_string()).expect("writer emitted invalid JSON");
        assert_eq!(reparsed, v, "parse(write(v)) != v");
        let pretty = Json::parse(&v.to_string_pretty()).expect("pretty writer emitted invalid JSON");
        assert_eq!(pretty, v, "pretty round-trip diverged");
    }
});
