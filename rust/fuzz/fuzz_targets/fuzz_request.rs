//! `Request::parse_line` on arbitrary bytes — the first thing a serve
//! connection does to every client line. Must never panic; accepted
//! requests must carry a sane id and a stable op name.

#![no_main]

use cggm::serve::{Op, Request, MAX_APPEND_ROWS};
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(line) = std::str::from_utf8(data) else {
        return;
    };
    if let Ok(req) = Request::parse_line(line) {
        // Ids are checked extractions: anything past 2^53 - 1 must have
        // been rejected, not silently rounded.
        assert!(req.id < (1u64 << 53));
        let name = req.op_name();
        assert!(
            matches!(
                name,
                "load" | "fit" | "path" | "cv" | "stat" | "evict" | "cancel" | "save"
                    | "export" | "append" | "refit" | "shutdown"
            ),
            "unexpected op name {name}"
        );
        if let Op::Load(_) = &req.op {
            assert!(req.dataset_name().is_some());
        }
        if let Op::Save(_) | Op::Export { .. } = &req.op {
            assert!(req.dataset_name().is_some());
        }
        if let Op::Append(a) = &req.op {
            // Exactly one source survived parsing, the inline row cap
            // held, and no non-finite value slipped through.
            assert!(a.rows.is_empty() != a.path.is_none());
            assert!(a.rows.len() <= MAX_APPEND_ROWS);
            assert!(a
                .rows
                .iter()
                .all(|(x, y)| x.iter().chain(y).all(|v| v.is_finite())));
        }
        if let Op::Cancel { job } = &req.op {
            // Checked u64 extraction, same contract as the request id.
            assert!(*job < (1u64 << 53));
        }
    }
});
