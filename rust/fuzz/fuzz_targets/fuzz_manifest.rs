//! Artifact + batch-job manifest parsers on arbitrary bytes: never panic,
//! and every accepted batch job carries an id (the correlation guarantee
//! `cggm batch` relies on).

#![no_main]

use cggm::runtime::manifest::{JobManifest, Manifest};
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    let _ = Manifest::parse(text);
    if let Ok(jobs) = JobManifest::parse(text) {
        for job in jobs.jobs() {
            assert!(job.get("id").is_some(), "job admitted without an id");
            assert!(job.as_obj().is_some(), "job admitted as a non-object");
        }
    }
});
