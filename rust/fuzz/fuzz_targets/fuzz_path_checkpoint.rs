//! λ-path checkpoint loader on arbitrary bytes. The loader's contract:
//! errors only on unreadable/header-less input, otherwise returns the
//! valid prefix — and never panics or makes a header-driven allocation
//! (dimension caps run before any `O(dims)` buffer exists).

#![no_main]

use cggm::coordinator::checkpoint;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if let Ok(state) = checkpoint::load_from(std::io::Cursor::new(data)) {
        assert!(state.valid_bytes as usize <= data.len());
        assert!(state.points.len() <= state.grid.len());
        // A surviving point implies a surviving model and vice versa.
        assert_eq!(state.points.is_empty(), state.model.is_none());
    }
});
