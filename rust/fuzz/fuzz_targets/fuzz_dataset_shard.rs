//! The `CGGMPAN1` panel-file header/shard-table parser on arbitrary bytes:
//! `read_meta` must never panic, never allocate proportionally to claimed
//! (unvalidated) dimensions, and every accepted shard table must satisfy
//! the v1 invariants the disk-backed dataset layer relies on — full-row
//! shards, contiguous per-space column ranges, balanced X/Y sample counts,
//! and payloads that lie entirely inside the file.

#![no_main]

use cggm::storage::{read_meta, Space, COL_CAP, DIM_CAP};
use libfuzzer_sys::fuzz_target;
use std::io::Cursor;

fuzz_target!(|data: &[u8]| {
    let Ok(meta) = read_meta(&mut Cursor::new(data)) else {
        return;
    };
    // Anything accepted must be safe to build a shard table over.
    assert!(meta.p >= 1 && meta.p as u64 <= DIM_CAP);
    assert!(meta.q >= 1 && meta.q as u64 <= DIM_CAP);
    assert!((meta.n as u64) <= COL_CAP);
    assert!(meta.data_end as usize <= data.len());
    let (mut n_x, mut n_y) = (0usize, 0usize);
    for s in &meta.shards {
        assert!(s.col_start < s.col_end, "empty shard admitted");
        let expect = match s.space {
            Space::X => &mut n_x,
            Space::Y => &mut n_y,
        };
        assert_eq!(s.col_start, *expect, "non-contiguous shard admitted");
        *expect = s.col_end;
        let rows = match s.space {
            Space::X => meta.p,
            Space::Y => meta.q,
        } as u64;
        let payload = rows * (s.col_end - s.col_start) as u64 * 8;
        let end = s.offset.checked_add(payload).expect("payload overflow admitted");
        assert!(end <= data.len() as u64, "payload past end of file admitted");
    }
    assert_eq!(n_x, meta.n, "X sample count disagrees with meta.n");
    assert_eq!(n_y, meta.n, "Y sample count disagrees with meta.n");
});
