//! CV checkpoint loader on arbitrary bytes: never panics, score-table
//! allocation is bounded by the header caps, and the valid prefix is
//! internally consistent.

#![no_main]

use cggm::coordinator::checkpoint;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if let Ok(state) = checkpoint::load_cv_from(std::io::Cursor::new(data)) {
        assert!(state.valid_bytes as usize <= data.len());
        assert_eq!(state.nll.len(), state.folds);
        assert_eq!(state.done.len(), state.folds);
        assert_eq!(state.fallbacks.len(), state.folds);
        for row in &state.nll {
            assert_eq!(row.len(), state.grid.len());
        }
        assert!(state.completed_folds() <= state.folds);
    }
});
