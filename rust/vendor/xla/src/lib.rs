//! API-compatible **stub** of the PJRT/XLA bindings the `cggm` crate links
//! against.
//!
//! The production build environment vendors the real `xla` crate (PJRT CPU
//! client + HLO-proto loading); this container does not ship it, so this
//! stub keeps the workspace compiling and makes every runtime entry point
//! fail cleanly with [`Error`]. `cggm::runtime::make_engine` treats that as
//! "artifacts unavailable" and falls back to the native GEMM engine, and the
//! PJRT oracle tests skip themselves when no manifest is present.
//!
//! Only the surface `cggm` actually calls is modeled; replace the `xla` path
//! dependency in `rust/Cargo.toml` with the real bindings to enable the
//! `xla` / `pallas` engines.

/// Error type mirroring the real crate's (string-backed here).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error::msg("xla stub: PJRT runtime not vendored in this build (native engine only)")
}

/// Host literal (dense tensor). The stub keeps the row-major data so the
/// pure host-side constructors behave, but nothing can be executed.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1(data: &[f64]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// 0-D literal.
    pub fn scalar(v: f64) -> Literal {
        Literal {
            data: vec![v],
            dims: vec![],
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error::msg(format!(
                "reshape: {} elements into {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a host vector. The stub supports only what a literal that
    /// never round-tripped through a device can honestly provide.
    pub fn to_vec<T: FromF64>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Decompose a tuple result.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Conversion helper for [`Literal::to_vec`].
pub trait FromF64 {
    fn from_f64(v: f64) -> Self;
}

impl FromF64 for f64 {
    fn from_f64(v: f64) -> f64 {
        v
    }
}

impl FromF64 for f32 {
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
}

/// HLO module handle. Never constructible through the stub.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. `cpu()` always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable handle. Unreachable through the stub (no client can
/// be constructed), but type-complete for the call sites.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_literals_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert_eq!(m.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(Literal::scalar(5.0).to_vec::<f64>().unwrap(), vec![5.0]);
    }

    #[test]
    fn runtime_entry_points_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
