//! Engine ablation bench: native blocked GEMM vs PJRT/XLA artifacts vs
//! PJRT/Pallas (interpret) artifacts across the three contraction layouts.
//! Quantifies the crossover size used by `XlaGemm::small` and the CPU cost
//! of the TPU-shaped Pallas kernels.

use cggm::bench::{Bench, BenchSet};
use cggm::gemm::native::NativeGemm;
use cggm::gemm::GemmEngine;
use cggm::linalg::dense::Mat;
use cggm::runtime::{artifact_dir, GemmVariant, XlaGemm};
use cggm::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("gemm");
    let mut rng = Rng::new(1);
    let native = NativeGemm::new(1);
    let engines: Vec<(&str, Box<dyn GemmEngine>)> = {
        let mut v: Vec<(&str, Box<dyn GemmEngine>)> = vec![("native", Box::new(NativeGemm::new(1)))];
        let dir = artifact_dir();
        if dir.join("manifest.json").exists() {
            for (name, variant, tile) in [
                ("xla@128", GemmVariant::Xla, 128),
                ("xla@256", GemmVariant::Xla, 256),
                ("pallas@128", GemmVariant::Pallas, 128),
            ] {
                match XlaGemm::load(&dir, tile, variant, 1) {
                    Ok(e) => v.push((name, Box::new(e))),
                    Err(e) => eprintln!("skipping {name}: {e}"),
                }
            }
        } else {
            eprintln!("artifacts not built; native only");
        }
        v
    };
    for &size in &[128usize, 256, 512] {
        let a = Mat::from_fn(size, size, |_, _| rng.normal());
        let b = Mat::from_fn(size, size, |_, _| rng.normal());
        let flops = 2.0 * (size as f64).powi(3);
        let mut c = Mat::zeros(size, size);
        for (name, eng) in &engines {
            if *name == "pallas@128" && size > 256 {
                continue; // interpret mode too slow beyond this
            }
            set.push(
                Bench::new(format!("gemm_nt/{name}/{size}"))
                    .iters(if *name == "pallas@128" { 3 } else { 8 })
                    .work(flops)
                    .run(|| eng.gemm_nt(1.0, &a, &b, 0.0, &mut c)),
            );
        }
        // Reference: same op through the plain-native path (sanity anchor).
        set.push(
            Bench::new(format!("gemm_mm/native/{size}"))
                .iters(8)
                .work(flops)
                .run(|| native.gemm(1.0, &a, &b, 0.0, &mut c)),
        );
    }
    set.finish();
}
