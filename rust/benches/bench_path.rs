//! λ-path bench: quantifies what the warm-started path driver buys —
//! (a) total outer iterations saved by seeding each point with the previous
//! solution, and (b) wall-clock for a full sweep, warm vs cold, on a shared
//! `SolverContext` (covariance statistics computed once per path).

use cggm::bench::{Bench, BenchSet};
use cggm::coordinator::{fit_path, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverKind};

fn main() {
    let eng = NativeGemm::new(1);
    let prob = datagen::chain::generate(150, 150, 100, 5);
    let base = SolveOptions {
        max_iter: 120,
        ..Default::default()
    };
    let warm_opts = PathOptions {
        points: 8,
        min_ratio: 0.05,
        lambdas: None,
        warm_start: true,
    };
    let cold_opts = PathOptions {
        warm_start: false,
        ..warm_opts.clone()
    };

    // Iteration-count comparison (the warm-start savings headline).
    let warm = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &warm_opts, &eng).unwrap();
    let cold = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &cold_opts, &eng).unwrap();
    println!(
        "# chain150 λ-path ({} points): warm {} iters / {:.2}s vs cold {} iters / {:.2}s",
        warm.points.len(),
        warm.total_iters(),
        warm.total_seconds,
        cold.total_iters(),
        cold.total_seconds,
    );
    for (w, c) in warm.points.iter().zip(&cold.points) {
        println!(
            "#   λ={:<8.4} warm {:>3} iters vs cold {:>3} iters",
            w.lam_l, w.iters, c.iters
        );
    }

    let mut set = BenchSet::new("path");
    for kind in [SolverKind::AltNewtonCd, SolverKind::NewtonCd] {
        for (tag, popts) in [("warm", &warm_opts), ("cold", &cold_opts)] {
            set.push(
                Bench::new(format!("path/chain150/{}/{tag}", kind.name()))
                    .warmup(1)
                    .iters(3)
                    .run(|| fit_path(kind, &prob.data, &base, popts, &eng).unwrap()),
            );
        }
    }
    set.finish();
}
