//! λ-path bench: quantifies what the path driver buys —
//! (a) total outer iterations saved by seeding each point with the previous
//! solution (warm vs cold), (b) coordinates examined with strong-rule
//! screening vs full re-screening at equal final objective, and (c)
//! wall-clock for a full sweep, all on a shared `SolverContext` (covariance
//! statistics computed once per path).

use cggm::bench::{Bench, BenchSet};
use cggm::cggm::active::ScreenRule;
use cggm::coordinator::{fit_path, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverKind};

fn main() {
    let eng = NativeGemm::new(1);
    let prob = datagen::chain::generate(150, 150, 100, 5);
    let base = SolveOptions {
        max_iter: 120,
        ..Default::default()
    };
    let screened_opts = PathOptions {
        points: 8,
        min_ratio: 0.05,
        lambdas: None,
        warm_start: true,
        screen: ScreenRule::Strong,
    };
    let warm_opts = PathOptions {
        screen: ScreenRule::Full,
        ..screened_opts.clone()
    };
    let cold_opts = PathOptions {
        warm_start: false,
        ..warm_opts.clone()
    };

    // Iteration-count comparison (the warm-start savings headline).
    let warm = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &warm_opts, &eng).unwrap();
    let cold = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &cold_opts, &eng).unwrap();
    println!(
        "# chain150 λ-path ({} points): warm {} iters / {:.2}s vs cold {} iters / {:.2}s",
        warm.points.len(),
        warm.total_iters(),
        warm.total_seconds,
        cold.total_iters(),
        cold.total_seconds,
    );
    for (w, c) in warm.points.iter().zip(&cold.points) {
        println!(
            "#   λ={:<8.4} warm {:>3} iters vs cold {:>3} iters",
            w.lam_l, w.iters, c.iters
        );
    }

    // Screening comparison (the strong-rule savings headline): same grid,
    // same warm starts, coordinates examined with and without the rule. The
    // final objectives must agree to ~solver precision — screening is an
    // optimization, not an approximation.
    let screened = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &screened_opts,
        &eng,
    )
    .unwrap();
    let (cs, cu) = (
        screened.total_coord_updates(),
        warm.total_coord_updates(),
    );
    let (fs, fu) = (
        screened.points.last().unwrap().f,
        warm.points.last().unwrap().f,
    );
    println!(
        "# screening: strong {} coord updates (+{} KKT-scan coords) vs \
         full {} ({:.2}x fewer updates), {} fallbacks, |Δf| = {:.2e}",
        cs,
        screened.total_kkt_scans(),
        cu,
        cu as f64 / cs.max(1) as f64,
        screened.screen_fallbacks,
        (fs - fu).abs(),
    );
    for (s, w) in screened.points.iter().zip(&warm.points) {
        println!(
            "#   λ={:<8.4} strong {:>9} (+{:>7} kkt) vs full {:>9}{}",
            s.lam_l,
            s.coord_updates,
            s.kkt_scans,
            w.coord_updates,
            if s.fallback { "  [fallback]" } else { "" }
        );
    }
    assert!(
        (fs - fu).abs() <= 1e-6 * fu.abs().max(1.0),
        "screened and unscreened paths disagree: {fs} vs {fu}"
    );
    assert!(
        2 * cs <= cu,
        "acceptance: screened must do >= 2x fewer coordinate updates \
         (strong {cs} vs full {cu})"
    );

    let mut set = BenchSet::new("path");
    for kind in [SolverKind::AltNewtonCd, SolverKind::NewtonCd] {
        for (tag, popts) in [
            ("strong", &screened_opts),
            ("warm", &warm_opts),
            ("cold", &cold_opts),
        ] {
            if tag == "strong" && !kind.supports_screen() {
                continue; // screening is inert for this solver — the "warm"
                          // leg already measures the identical run
            }
            set.push(
                Bench::new(format!("path/chain150/{}/{tag}", kind.name()))
                    .warmup(1)
                    .iters(3)
                    .run(|| fit_path(kind, &prob.data, &base, popts, &eng).unwrap()),
            );
        }
    }
    set.finish();
}
