//! λ-path bench: quantifies what the path driver buys —
//! (a) total outer iterations saved by seeding each point with the previous
//! solution (warm vs cold), (b) coordinates examined with strong-rule
//! screening vs full re-screening at equal final objective, (c) clustering
//! partitions the block solver *didn't* have to rebuild thanks to the
//! context-persistent partition cache, (d) checkpoint write overhead and the
//! points a resumed sweep skips, and (e) wall-clock for a full sweep, all on
//! a shared `SolverContext` (covariance statistics computed once per path).
//!
//! Besides the human-readable report it writes `BENCH_PATH.json` — the
//! machine-readable trajectory future PRs regress against (docs/PERF.md).

use cggm::bench::{write_bench_json, Bench, BenchSet};
use cggm::util::json::Json;
use cggm::cggm::active::ScreenRule;
use cggm::coordinator::{fit_path, fit_path_in_context, PathOptions};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{SolveOptions, SolverContext, SolverKind};
use cggm::util::membudget::MemBudget;

fn main() {
    let eng = NativeGemm::new(1);
    let prob = datagen::chain::generate(150, 150, 100, 5);
    let base = SolveOptions {
        max_iter: 120,
        ..Default::default()
    };
    let screened_opts = PathOptions {
        points: 8,
        min_ratio: 0.05,
        lambdas: None,
        warm_start: true,
        screen: ScreenRule::Strong,
        ..Default::default()
    };
    let warm_opts = PathOptions {
        screen: ScreenRule::Full,
        ..screened_opts.clone()
    };
    let cold_opts = PathOptions {
        warm_start: false,
        ..warm_opts.clone()
    };

    // Iteration-count comparison (the warm-start savings headline).
    let warm = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &warm_opts, &eng).unwrap();
    let cold = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &cold_opts, &eng).unwrap();
    println!(
        "# chain150 λ-path ({} points): warm {} iters / {:.2}s vs cold {} iters / {:.2}s",
        warm.points.len(),
        warm.total_iters(),
        warm.total_seconds,
        cold.total_iters(),
        cold.total_seconds,
    );
    for (w, c) in warm.points.iter().zip(&cold.points) {
        println!(
            "#   λ={:<8.4} warm {:>3} iters vs cold {:>3} iters",
            w.lam_l, w.iters, c.iters
        );
    }

    // Screening comparison (the strong-rule savings headline): same grid,
    // same warm starts, coordinates examined with and without the rule. The
    // final objectives must agree to ~solver precision — screening is an
    // optimization, not an approximation.
    let screened = fit_path(
        SolverKind::AltNewtonCd,
        &prob.data,
        &base,
        &screened_opts,
        &eng,
    )
    .unwrap();
    let (cs, cu) = (
        screened.total_coord_updates(),
        warm.total_coord_updates(),
    );
    let (fs, fu) = (
        screened.points.last().unwrap().f,
        warm.points.last().unwrap().f,
    );
    println!(
        "# screening: strong {} coord updates (+{} KKT-scan coords) vs \
         full {} ({:.2}x fewer updates), {} fallbacks, |Δf| = {:.2e}",
        cs,
        screened.total_kkt_scans(),
        cu,
        cu as f64 / cs.max(1) as f64,
        screened.screen_fallbacks,
        (fs - fu).abs(),
    );
    for (s, w) in screened.points.iter().zip(&warm.points) {
        println!(
            "#   λ={:<8.4} strong {:>9} (+{:>7} kkt) vs full {:>9}{}",
            s.lam_l,
            s.coord_updates,
            s.kkt_scans,
            w.coord_updates,
            if s.fallback { "  [fallback]" } else { "" }
        );
    }
    assert!(
        (fs - fu).abs() <= 1e-6 * fu.abs().max(1.0),
        "screened and unscreened paths disagree: {fs} vs {fu}"
    );
    assert!(
        2 * cs <= cu,
        "acceptance: screened must do >= 2x fewer coordinate updates \
         (strong {cs} vs full {cu})"
    );

    // Clustering persistence (block solver): along the path the partition is
    // rebuilt only on active-set churn; a forced-rebuild ablation shows what
    // the cache saves while reaching the same objectives.
    let bcd_popts = PathOptions {
        points: 6,
        min_ratio: 0.1,
        screen: ScreenRule::Full,
        ..Default::default()
    };
    let mk_bcd = |churn: f64| SolveOptions {
        max_iter: 120,
        budget: MemBudget::new(512 * 1024),
        recluster_churn: churn,
        ..Default::default()
    };
    let cached_base = mk_bcd(0.2);
    let cached_ctx = SolverContext::new(&prob.data, &cached_base, &eng);
    let cached =
        fit_path_in_context(SolverKind::AltNewtonBcd, &cached_ctx, &cached_base, &bcd_popts)
            .unwrap();
    let forced_base = mk_bcd(-1.0);
    let forced_ctx = SolverContext::new(&prob.data, &forced_base, &eng);
    let forced =
        fit_path_in_context(SolverKind::AltNewtonBcd, &forced_ctx, &forced_base, &bcd_popts)
            .unwrap();
    let (rc, rf) = (
        cached.points.iter().map(|p| p.reclusterings).sum::<usize>(),
        forced.points.iter().map(|p| p.reclusterings).sum::<usize>(),
    );
    println!(
        "# bcd clustering persistence: {} rebuilds cached vs {} forced \
         ({:.2}s vs {:.2}s), |Δf| = {:.2e}",
        rc,
        rf,
        cached.total_seconds,
        forced.total_seconds,
        (cached.points.last().unwrap().f - forced.points.last().unwrap().f).abs(),
    );
    assert!(
        rc <= rf,
        "persistent partition must not rebuild more than the forced ablation"
    );
    {
        let (fc, ff) = (
            cached.points.last().unwrap().f,
            forced.points.last().unwrap().f,
        );
        // Partition choice changes CD update order, so the runs agree to the
        // solver's stopping tolerance (the tight 1e-6 bar lives in
        // cluster_persistence_tests, which converges to tol = 1e-5).
        assert!(
            (fc - ff).abs() <= 1e-4 * ff.abs().max(1.0),
            "clustering persistence changed the optimum: {fc} vs {ff}"
        );
    }

    // Checkpoint/resume: write a checkpoint during a screened sweep, drop
    // the second half, and resume — the resumed sweep must reproduce the
    // uninterrupted objectives while refitting only the dropped points.
    let ck = std::env::temp_dir().join("cggm_bench_path_ckpt.jsonl");
    let _ = std::fs::remove_file(&ck);
    let ck_opts = PathOptions {
        checkpoint: Some(ck.clone()),
        ..screened_opts.clone()
    };
    let ckpointed = fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &ck_opts, &eng).unwrap();
    let keep = 1 + ckpointed.points.len() / 2; // header + half the points
    let text = std::fs::read_to_string(&ck).unwrap();
    let prefix: String = text.lines().take(keep).map(|l| format!("{l}\n")).collect();
    std::fs::write(&ck, prefix).unwrap();
    let resume_opts = PathOptions {
        resume: true,
        ..ck_opts.clone()
    };
    let resumed =
        fit_path(SolverKind::AltNewtonCd, &prob.data, &base, &resume_opts, &eng).unwrap();
    println!(
        "# checkpoint: full sweep {:.2}s (+checkpoint io) vs resume {:.2}s \
         ({} points carried, {} refitted)",
        ckpointed.total_seconds,
        resumed.total_seconds,
        resumed.resumed_points,
        resumed.points.len() - resumed.resumed_points,
    );
    for (a, b) in ckpointed.points.iter().zip(&resumed.points) {
        assert!(
            (a.f - b.f).abs() <= 1e-8 * a.f.abs().max(1.0),
            "resume diverged at λ={}: {} vs {}",
            a.lam_l,
            a.f,
            b.f
        );
    }
    let _ = std::fs::remove_file(&ck);

    let mut set = BenchSet::new("path");
    for kind in [SolverKind::AltNewtonCd, SolverKind::NewtonCd] {
        for (tag, popts) in [
            ("strong", &screened_opts),
            ("warm", &warm_opts),
            ("cold", &cold_opts),
        ] {
            if tag == "strong" && !kind.supports_screen() {
                continue; // screening is inert for this solver — the "warm"
                          // leg already measures the identical run
            }
            set.push(
                Bench::new(format!("path/chain150/{}/{tag}", kind.name()))
                    .warmup(1)
                    .iters(3)
                    .run(|| fit_path(kind, &prob.data, &base, popts, &eng).unwrap()),
            );
        }
    }

    // Machine-readable trajectory: the headline path comparisons plus every
    // timed row, so future PRs can diff wall-clock and work counters.
    let doc = Json::obj(vec![
        ("schema", Json::str("cggm-bench-path/v1")),
        (
            "problem",
            Json::obj(vec![
                ("workload", Json::str("chain")),
                ("p", Json::num(150.0)),
                ("q", Json::num(150.0)),
                ("n", Json::num(100.0)),
                ("points", Json::num(warm.points.len() as f64)),
            ]),
        ),
        (
            "warm_vs_cold",
            Json::obj(vec![
                ("warm_iters", Json::num(warm.total_iters() as f64)),
                ("cold_iters", Json::num(cold.total_iters() as f64)),
                ("warm_seconds", Json::num(warm.total_seconds)),
                ("cold_seconds", Json::num(cold.total_seconds)),
            ]),
        ),
        (
            "screening",
            Json::obj(vec![
                ("strong_coord_updates", Json::num(cs as f64)),
                ("strong_kkt_scans", Json::num(screened.total_kkt_scans() as f64)),
                ("full_coord_updates", Json::num(cu as f64)),
                ("fallbacks", Json::num(screened.screen_fallbacks as f64)),
                ("abs_delta_f", Json::num((fs - fu).abs())),
            ]),
        ),
        (
            "clustering_persistence",
            Json::obj(vec![
                ("cached_rebuilds", Json::num(rc as f64)),
                ("forced_rebuilds", Json::num(rf as f64)),
                ("cached_seconds", Json::num(cached.total_seconds)),
                ("forced_seconds", Json::num(forced.total_seconds)),
            ]),
        ),
        (
            "checkpoint",
            Json::obj(vec![
                ("full_seconds", Json::num(ckpointed.total_seconds)),
                ("resume_seconds", Json::num(resumed.total_seconds)),
                ("resumed_points", Json::num(resumed.resumed_points as f64)),
                (
                    "refitted_points",
                    Json::num((resumed.points.len() - resumed.resumed_points) as f64),
                ),
            ]),
        ),
        ("legs", Json::arr(set.rows.iter().map(|r| r.to_json()))),
    ]);
    write_bench_json("PATH", &doc);
    set.finish();
}
