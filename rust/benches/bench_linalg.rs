//! Linear-algebra substrate bench: the paper's per-iteration primitives —
//! Σ-column extraction (CG vs sparse-Cholesky solve vs dense inverse),
//! sparse Cholesky factorization, and graph clustering.

use cggm::bench::{Bench, BenchSet};
use cggm::datagen::chain::chain_lambda;
use cggm::datagen::cluster_graph::{clustered_lambda, ClusterOptions as GenOpts};
use cggm::gemm::native::NativeGemm;
use cggm::graph::cluster::{cluster, ClusterOptions};
use cggm::graph::Graph;
use cggm::linalg::cg::CgSolver;
use cggm::linalg::chol_dense::DenseChol;
use cggm::linalg::chol_sparse::SparseChol;
use cggm::linalg::dense::Mat;
use cggm::util::rng::Rng;
use cggm::util::threadpool::Parallelism;

fn main() {
    let mut set = BenchSet::new("linalg");
    let eng = NativeGemm::new(1);
    let par = Parallelism::new(1);
    let mut rng = Rng::new(2);

    for &q in &[500usize, 2000] {
        let lam = chain_lambda(q);
        // CG: 32 columns of Σ.
        let solver = CgSolver::new(lam.to_csr(), 1e-10, 20 * q);
        let cols: Vec<usize> = (0..32).map(|i| i * (q / 32)).collect();
        let mut out = Mat::zeros(cols.len(), q);
        set.push(
            Bench::new(format!("sigma_cols_cg/chain/q{q}/32cols"))
                .iters(5)
                .run(|| solver.inverse_columns(&cols, &mut out, &par)),
        );
        // Sparse Cholesky factor + 32 solves.
        set.push(
            Bench::new(format!("sparse_chol_factor/chain/q{q}"))
                .iters(5)
                .run(|| SparseChol::factor(&lam, true, usize::MAX).unwrap()),
        );
        let chol = SparseChol::factor(&lam, true, usize::MAX).unwrap();
        let e0: Vec<f64> = (0..q).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        set.push(
            Bench::new(format!("sparse_chol_solve/chain/q{q}"))
                .iters(10)
                .run(|| chol.solve(&e0)),
        );
        if q <= 500 {
            let dense = lam.to_dense();
            set.push(
                Bench::new(format!("dense_chol_inverse/q{q}"))
                    .iters(3)
                    .run(|| DenseChol::factor(&dense, &eng).unwrap().inverse(&eng)),
            );
        }
    }
    // Clustering on a clustered random graph (the partitioner's real input).
    let lam = clustered_lambda(
        2000,
        &mut rng,
        &GenOpts {
            cluster_size: 100,
            ..Default::default()
        },
    );
    let g = Graph::from_sym_pattern(&lam);
    set.push(
        Bench::new("cluster/2000nodes/k8")
            .iters(5)
            .run(|| cluster(&g, 8, &ClusterOptions::default())),
    );
    set.finish();
}
