//! Kernel-level perf trajectory: packed-GEMM and colored-CD-sweep
//! microbenches across 1/2/4 threads, plus tiled-vs-eager Gram statistic
//! builds and a budget-capped tiled BCD solve, written to
//! `BENCH_KERNELS.json` so future PRs have a machine-readable baseline to
//! regress against (see docs/PERF.md for the schema and how to read it).
//!
//! Flags (after `--`):
//! - `--smoke`        small sizes / few iterations, no scaling assertions
//!                    (CI runners may have < 4 cores);
//! - `--max-threads N` cap the thread sweep (default 4).
//!
//! Acceptance (full mode on a ≥4-core machine): the colored CD sweep must
//! reach ≥1.8× at 4 threads vs 1, and packed GEMM ≥1.5× — the ISSUE-4
//! floors; the JSON records pass/fail either way.

use cggm::bench::{write_bench_json, Bench, BenchSet, BenchStats};
use cggm::cggm::active::{lambda_active_dense, theta_active_dense};
use cggm::cggm::Objective;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::gemm::GemmEngine;
use cggm::graph::coloring::{color_classes, validate_classes, ConflictSpace};
use cggm::linalg::dense::Mat;
use cggm::solvers::cd_common::{
    lambda_cd_pass, lambda_cd_pass_colored, theta_cd_pass_direct, theta_cd_pass_direct_colored,
    ColoredScratch,
};
use cggm::cggm::tiles::TileStore;
use cggm::solvers::{solve, SolveOptions, SolverContext, SolverKind, StatMode};
use cggm::util::json::Json;
use cggm::util::membudget::MemBudget;
use cggm::util::rng::Rng;
use cggm::util::threadpool::Parallelism;

struct Leg {
    family: &'static str,
    threads: usize,
    coord_updates: usize,
    stats: BenchStats,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let max_threads: usize = args
        .iter()
        .position(|a| a == "--max-threads")
        .and_then(|k| args.get(k + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let thread_sweep: Vec<usize> = [1usize, 2, 4]
        .into_iter()
        .filter(|&t| t <= max_threads.max(1))
        .collect();
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let (bench_iters, warmup) = if smoke { (3, 1) } else { (9, 2) };
    let mut set = BenchSet::new("kernels");
    let mut legs: Vec<Leg> = Vec::new();

    // ---------------------------------------------------------- CD sweeps
    // Medium synthetic problem (the ISSUE-4 acceptance target): a chain
    // CGGM whose dense caches (Σ, Ψ, S_yy, S_xx, Vᵀ) feed the real
    // lambda/theta passes — the benches time exactly the solver hot loops.
    let (q, n) = if smoke { (64, 80) } else { (192, 140) };
    let prob = datagen::chain::generate(q, q, n, 7);
    let eng = NativeGemm::new(1);
    let opts = SolveOptions::default();
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let syy = ctx.syy().unwrap();
    let sxx = ctx.sxx().unwrap();
    let sxy = ctx.sxy().unwrap();
    let sxx_diag: Vec<f64> = ctx.sxx_diag().to_vec();
    let obj = Objective::new(&prob.data, 0.0, 0.0);
    let factor = obj.factor_lambda(&prob.truth.lambda, &eng).unwrap();
    let sigma = factor.inverse_dense(&eng);
    let rt = prob.data.xtheta_t(&prob.truth.theta);
    let psi = obj.psi_dense(&sigma, &rt, &eng);
    // Gradients at the truth → realistic active sets (λ small enough to
    // keep the sweep busy).
    let gl = obj.grad_lambda_dense(&sigma, &psi, &eng);
    let gt = obj.grad_theta_dense(&sigma, &rt, &eng);
    let (lam_l, lam_t) = (0.05, 0.05);
    let (active_l, _) = lambda_active_dense(&gl, &prob.truth.lambda, lam_l);
    let (active_t, _) = theta_active_dense(&gt, &prob.truth.theta, lam_t);
    println!(
        "# cd sweep problem: q={q} n={n}, |S_L|={}, |S_T|={}",
        active_l.len(),
        active_t.len()
    );
    let classes_l = color_classes(&active_l, ConflictSpace::Symmetric(q));
    validate_classes(&active_l, &classes_l, ConflictSpace::Symmetric(q)).unwrap();
    let classes_t = color_classes(&active_t, ConflictSpace::Bipartite(q, q));
    validate_classes(&active_t, &classes_t, ConflictSpace::Bipartite(q, q)).unwrap();
    println!(
        "# colored: {} Λ classes, {} Θ classes",
        classes_l.len(),
        classes_t.len()
    );

    // Serial reference sweeps.
    {
        let stats = Bench::new("cd_lambda/serial")
            .warmup(warmup)
            .iters(bench_iters)
            .run(|| {
                let mut delta = cggm::linalg::sparse::SpRowMat::zeros(q, q);
                let mut w = Mat::zeros(q, q);
                lambda_cd_pass(
                    &active_l,
                    syy,
                    &sigma,
                    &psi,
                    &prob.truth.lambda,
                    &mut delta,
                    &mut w,
                    lam_l,
                    None,
                )
            });
        legs.push(Leg {
            family: "cd_lambda_serial",
            threads: 1,
            coord_updates: active_l.len(),
            stats: stats.clone(),
        });
        set.push(stats);
        let stats = Bench::new("cd_theta/serial")
            .warmup(warmup)
            .iters(bench_iters)
            .run(|| {
                let mut theta = prob.truth.theta.clone();
                let mut vt = Mat::zeros(q, q);
                theta_cd_pass_direct(
                    &active_t,
                    sxx,
                    &sxx_diag,
                    sxy,
                    &sigma,
                    &mut theta,
                    &mut vt,
                    lam_t,
                )
            });
        legs.push(Leg {
            family: "cd_theta_serial",
            threads: 1,
            coord_updates: active_t.len(),
            stats: stats.clone(),
        });
        set.push(stats);
    }

    // Colored sweeps across the thread sweep.
    for &t in &thread_sweep {
        let par = Parallelism::new(t);
        let mut scratch = ColoredScratch::default();
        let stats = Bench::new(format!("cd_lambda/colored/t{t}"))
            .warmup(warmup)
            .iters(bench_iters)
            .run(|| {
                let mut delta = cggm::linalg::sparse::SpRowMat::zeros(q, q);
                let mut w = Mat::zeros(q, q);
                lambda_cd_pass_colored(
                    &classes_l,
                    syy,
                    &sigma,
                    &psi,
                    &prob.truth.lambda,
                    &mut delta,
                    &mut w,
                    lam_l,
                    None,
                    &par,
                    &mut scratch,
                )
            });
        legs.push(Leg {
            family: "cd_lambda_colored",
            threads: t,
            coord_updates: active_l.len(),
            stats: stats.clone(),
        });
        set.push(stats);
        let mut scratch = ColoredScratch::default();
        let stats = Bench::new(format!("cd_theta/colored/t{t}"))
            .warmup(warmup)
            .iters(bench_iters)
            .run(|| {
                let mut theta = prob.truth.theta.clone();
                let mut vt = Mat::zeros(q, q);
                theta_cd_pass_direct_colored(
                    &classes_t,
                    sxx,
                    &sxx_diag,
                    sxy,
                    &sigma,
                    &mut theta,
                    &mut vt,
                    lam_t,
                    &par,
                    &mut scratch,
                )
            });
        legs.push(Leg {
            family: "cd_theta_colored",
            threads: t,
            coord_updates: active_t.len(),
            stats: stats.clone(),
        });
        set.push(stats);
    }

    // --------------------------------------------------------------- GEMM
    let size = if smoke { 192 } else { 384 };
    let mut rng = Rng::new(1);
    let a = Mat::from_fn(size, size, |_, _| rng.normal());
    let b = Mat::from_fn(size, size, |_, _| rng.normal());
    let flops = 2.0 * (size as f64).powi(3);
    for &t in &thread_sweep {
        let native = NativeGemm::new(t);
        for (tag, family) in [("gemm", "gemm_nn"), ("gemm_tn", "gemm_tn"), ("gemm_nt", "gemm_nt")]
        {
            let mut c = Mat::zeros(size, size);
            let stats = Bench::new(format!("{tag}/{size}/t{t}"))
                .warmup(warmup)
                .iters(bench_iters)
                .work(flops)
                .run(|| match tag {
                    "gemm" => native.gemm(1.0, &a, &b, 0.0, &mut c),
                    "gemm_tn" => native.gemm_tn(1.0, &a, &b, 0.0, &mut c),
                    _ => native.gemm_nt(1.0, &a, &b, 0.0, &mut c),
                });
            legs.push(Leg {
                family,
                threads: t,
                coord_updates: 0,
                stats: stats.clone(),
            });
            set.push(stats);
        }
    }

    // ------------------------------------------------------- tiled statistics
    // ISSUE-6 acceptance shape: the tiled on-demand Gram build vs the eager
    // dense build, plus a BCD solve whose budget is strictly below the dense
    // S_xx footprint. `tiled_diag` shows the laziness win — only the touched
    // block-diagonal is ever built.
    let (tp, tq, tn) = if smoke { (96, 16, 60) } else { (256, 32, 120) };
    let copts = datagen::cluster_graph::ClusterOptions {
        cluster_size: 8,
        hub_coeff: 100.0,
        ..Default::default()
    };
    let tprob = datagen::cluster_graph::generate(tp, tq, tn, 11, &copts);
    let tile = 32usize;
    let (nbx, nby) = (tp.div_ceil(tile), tq.div_ceil(tile));
    let stats = Bench::new("stat_build/eager")
        .warmup(warmup)
        .iters(bench_iters)
        .run(|| {
            let c = SolverContext::new(&tprob.data, &opts, &eng);
            c.sxx().unwrap();
            c.sxy().unwrap();
        });
    legs.push(Leg {
        family: "stat_build_eager",
        threads: 1,
        coord_updates: 0,
        stats: stats.clone(),
    });
    set.push(stats);
    let stats = Bench::new("stat_build/tiled_full")
        .warmup(warmup)
        .iters(bench_iters)
        .run(|| {
            let ts = TileStore::new(&tprob.data, &eng, MemBudget::unlimited(), tile);
            for bi in 0..nbx {
                for bj in bi..nbx {
                    ts.sxx_entry(bi * tile, bj * tile);
                }
            }
            for bi in 0..nbx {
                for bj in 0..nby {
                    ts.sxy_entry(bi * tile, bj * tile);
                }
            }
        });
    legs.push(Leg {
        family: "stat_build_tiled_full",
        threads: 1,
        coord_updates: 0,
        stats: stats.clone(),
    });
    set.push(stats);
    let stats = Bench::new("stat_build/tiled_diag")
        .warmup(warmup)
        .iters(bench_iters)
        .run(|| {
            let ts = TileStore::new(&tprob.data, &eng, MemBudget::unlimited(), tile);
            for b in 0..nbx {
                ts.sxx_entry(b * tile, b * tile);
            }
        });
    legs.push(Leg {
        family: "stat_build_tiled_diag",
        threads: 1,
        coord_updates: 0,
        stats: stats.clone(),
    });
    set.push(stats);

    // Budget-capped BCD: dense-mode solve vs tiled under cap = dense S_xx / 2.
    let solve_iters = if smoke { 2 } else { 3 };
    let bcd_opts = SolveOptions {
        lam_l: 0.1,
        lam_t: 0.1,
        max_iter: 60,
        ..Default::default()
    };
    let stats = Bench::new("bcd_solve/dense")
        .warmup(1)
        .iters(solve_iters)
        .run(|| {
            solve(SolverKind::AltNewtonBcd, &tprob.data, &bcd_opts, &eng).unwrap();
        });
    legs.push(Leg {
        family: "bcd_solve_dense",
        threads: 1,
        coord_updates: 0,
        stats: stats.clone(),
    });
    set.push(stats);
    let dense_sxx_bytes = 8 * tp * tp;
    let cap = dense_sxx_bytes / 2;
    let mut capped_opts = bcd_opts.clone();
    capped_opts.stat_mode = StatMode::Tiled(tile);
    capped_opts.budget = MemBudget::new(cap);
    let stats = Bench::new("bcd_solve/tiled_capped")
        .warmup(1)
        .iters(solve_iters)
        .run(|| {
            solve(SolverKind::AltNewtonBcd, &tprob.data, &capped_opts, &eng).unwrap();
        });
    legs.push(Leg {
        family: "bcd_solve_tiled_capped",
        threads: 1,
        coord_updates: 0,
        stats: stats.clone(),
    });
    set.push(stats);
    // One more instrumented run for the machine-readable tile counters.
    let capped = solve(SolverKind::AltNewtonBcd, &tprob.data, &capped_opts, &eng).unwrap();
    println!(
        "# tiled bcd (p={tp} q={tq} tile={tile}, cap {cap} B < dense S_xx {dense_sxx_bytes} B): \
         {} of {} tiles, {} evictions, {} spills",
        capped.trace.tiles_computed,
        capped.trace.total_tiles,
        capped.trace.tile_evictions,
        capped.trace.tile_spills
    );

    // ------------------------------------------------- scaling + trajectory
    let median_of = |family: &str, t: usize| -> Option<f64> {
        legs.iter()
            .find(|l| l.family == family && l.threads == t)
            .map(|l| l.stats.median)
    };
    let top = *thread_sweep.last().unwrap_or(&1);
    let mut scaling = Vec::new();
    let mut failures = Vec::new();
    for (family, floor) in [
        ("cd_lambda_colored", 1.8),
        ("cd_theta_colored", 1.8),
        ("gemm_nn", 1.5),
        ("gemm_tn", 1.5),
        ("gemm_nt", 1.5),
    ] {
        let (t1, ttop) = match (median_of(family, 1), median_of(family, top)) {
            (Some(a), Some(b)) => (a, b),
            _ => continue,
        };
        let speedup = t1 / ttop;
        // Floors only bind for the full bench at 4 threads with the cores
        // to back it — otherwise the numbers are recorded but advisory.
        let enforced = !smoke && top >= 4 && cpus >= 4;
        let pass = speedup >= floor;
        println!(
            "# scaling {family}: t1 {:.3}ms → t{top} {:.3}ms = {speedup:.2}x \
             (floor {floor}x{})",
            t1 * 1e3,
            ttop * 1e3,
            if enforced {
                if pass {
                    ", pass"
                } else {
                    ", FAIL"
                }
            } else {
                ", advisory"
            }
        );
        if enforced && !pass {
            failures.push(format!("{family}: {speedup:.2}x < {floor}x"));
        }
        scaling.push(Json::obj(vec![
            ("family", Json::str(family)),
            ("threads", Json::num(top as f64)),
            ("speedup", Json::num(speedup)),
            ("floor", Json::num(floor)),
            ("enforced", Json::Bool(enforced)),
            ("pass", Json::Bool(pass)),
        ]));
    }

    let doc = Json::obj(vec![
        ("schema", Json::str("cggm-bench-kernels/v1")),
        ("smoke", Json::Bool(smoke)),
        ("cpus", Json::num(cpus as f64)),
        (
            "threads",
            Json::arr(thread_sweep.iter().map(|&t| Json::num(t as f64))),
        ),
        (
            "problem",
            Json::obj(vec![
                ("q", Json::num(q as f64)),
                ("n", Json::num(n as f64)),
                ("gemm_size", Json::num(size as f64)),
                ("active_lambda", Json::num(active_l.len() as f64)),
                ("active_theta", Json::num(active_t.len() as f64)),
                ("lambda_classes", Json::num(classes_l.len() as f64)),
                ("theta_classes", Json::num(classes_t.len() as f64)),
            ]),
        ),
        (
            "tiled",
            Json::obj(vec![
                ("p", Json::num(tp as f64)),
                ("q", Json::num(tq as f64)),
                ("n", Json::num(tn as f64)),
                ("tile", Json::num(tile as f64)),
                ("budget_cap_bytes", Json::num(cap as f64)),
                ("dense_sxx_bytes", Json::num(dense_sxx_bytes as f64)),
                ("tiles_computed", Json::num(capped.trace.tiles_computed as f64)),
                ("total_tiles", Json::num(capped.trace.total_tiles as f64)),
                ("tile_evictions", Json::num(capped.trace.tile_evictions as f64)),
                ("tile_spills", Json::num(capped.trace.tile_spills as f64)),
            ]),
        ),
        (
            "legs",
            Json::arr(legs.iter().map(|l| {
                let mut o = match l.stats.to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!("to_json returns an object"),
                };
                o.insert("family".into(), Json::str(l.family));
                o.insert("threads".into(), Json::num(l.threads as f64));
                o.insert(
                    "coord_updates".into(),
                    Json::num(l.coord_updates as f64),
                );
                Json::Obj(o)
            })),
        ),
        ("scaling", Json::arr(scaling)),
    ]);
    write_bench_json("KERNELS", &doc);
    set.finish();
    if !failures.is_empty() {
        panic!("kernel scaling floors not met: {failures:?}");
    }
}
