//! Block-machinery ablations (paper §4 design choices):
//! - block CD sweep cost with clustering vs contiguous blocks;
//! - budget sweep (cache size vs time);
//! - L1 ablation: the Pallas cd_sweep artifact vs the native Rust CD pass
//!   on an identical Λ-block (cross-layer equivalence + cost).

use cggm::bench::{Bench, BenchSet};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::gemm::GemmEngine;
use cggm::linalg::dense::Mat;
use cggm::linalg::sparse::SpRowMat;
use cggm::runtime::{artifact_dir, compile_artifact, manifest::Manifest};
use cggm::solvers::cd_common::lambda_cd_pass;
use cggm::solvers::{solve, SolveOptions, SolverKind};
use cggm::util::membudget::MemBudget;
use cggm::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("blocks");
    let eng = NativeGemm::new(1);
    let prob = datagen::cluster_graph::generate(
        400,
        300,
        150,
        7,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 50,
            hub_coeff: 3.0,
            ..Default::default()
        },
    );
    // Clustering ablation under a tight budget.
    for (name, clustering) in [("clustered", true), ("contiguous", false)] {
        let opts = SolveOptions {
            lam_l: 0.9,
            lam_t: 0.9,
            max_iter: 40,
            clustering,
            budget: MemBudget::new(2 << 20),
            ..Default::default()
        };
        set.push(
            Bench::new(format!("bcd_sweep/{name}/2MB"))
                .warmup(1)
                .iters(3)
                .run(|| solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng).unwrap()),
        );
    }
    // Budget sweep.
    for budget_mb in [1usize, 8, 64] {
        let opts = SolveOptions {
            lam_l: 0.9,
            lam_t: 0.9,
            max_iter: 40,
            budget: MemBudget::new(budget_mb << 20),
            ..Default::default()
        };
        set.push(
            Bench::new(format!("bcd_sweep/budget/{budget_mb}MB"))
                .warmup(1)
                .iters(3)
                .run(|| solve(SolverKind::AltNewtonBcd, &prob.data, &opts, &eng).unwrap()),
        );
    }

    // L1 ablation: Pallas cd_sweep artifact vs native CD pass on one block.
    let dir = artifact_dir();
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
        if let Some(entry) = manifest.find("cd_sweep", None, None) {
            let b = entry.block.unwrap_or(32);
            let client = xla::PjRtClient::cpu().unwrap();
            let exe = compile_artifact(&client, &dir, entry).unwrap();
            let mut rng = Rng::new(9);
            // Random SPD block problem.
            let mk_spd = |rng: &mut Rng, scale: f64| {
                let m = Mat::from_fn(b + 2, b, |_, _| rng.normal());
                let mut s = Mat::zeros(b, b);
                NativeGemm::new(1).gemm_tn(1.0, &m, &m, 0.0, &mut s);
                for i in 0..b {
                    s[(i, i)] += scale;
                }
                s.symmetrize();
                s
            };
            let sigma = mk_spd(&mut rng, b as f64);
            let psi = mk_spd(&mut rng, 0.0);
            let syy = mk_spd(&mut rng, 1.0);
            let lam_mat = Mat::eye(b);
            let mask = Mat::from_fn(b, b, |i, j| if (i + j) % 3 != 0 || i == j { 1.0 } else { 0.0 });
            let reg = 0.3f64;
            let lit = |m: &Mat| {
                xla::Literal::vec1(m.data())
                    .reshape(&[b as i64, b as i64])
                    .unwrap()
            };
            set.push(
                Bench::new(format!("cd_sweep/pallas_artifact/b{b}"))
                    .iters(5)
                    .run(|| {
                        let args = vec![
                            lit(&syy),
                            lit(&sigma),
                            lit(&psi),
                            lit(&lam_mat),
                            lit(&mask),
                            xla::Literal::vec1(&[reg]).reshape(&[1, 1]).unwrap(),
                            lit(&Mat::zeros(b, b)),
                            lit(&Mat::zeros(b, b)),
                        ];
                        exe.execute::<xla::Literal>(&args).unwrap()[0][0]
                            .to_literal_sync()
                            .unwrap()
                    }),
            );
            // Native equivalent.
            let lambda_sp = SpRowMat::eye(b);
            let mut active = Vec::new();
            for i in 0..b {
                for j in i..b {
                    if mask[(i, j)] != 0.0 {
                        active.push((i, j));
                    }
                }
            }
            set.push(
                Bench::new(format!("cd_sweep/native/b{b}"))
                    .iters(50)
                    .run(|| {
                        let mut delta = SpRowMat::zeros(b, b);
                        let mut w = Mat::zeros(b, b);
                        lambda_cd_pass(
                            &active, &syy, &sigma, &psi, &lambda_sp, &mut delta, &mut w, reg,
                            None,
                        );
                        delta
                    }),
            );
        }
    } else {
        eprintln!("artifacts not built; skipping cd_sweep ablation");
    }
    set.finish();
}
