//! Out-of-core storage bench: what solving against disk-backed panels
//! costs versus the fully resident dataset, and how the panel cache
//! degrades as its budget shrinks below the working set.
//!
//! One chain problem is written to a sharded `CGGMPAN1` panel file and
//! then fit three ways on identical data: fully resident, disk-backed
//! with a cache generous enough to hold every panel, and disk-backed with
//! a cache far below the dense footprint (forcing LRU eviction and
//! re-reads). All three must reach the same optimum at 1e-6 — out-of-core
//! is a memory trade, never an accuracy trade — so the interesting
//! numbers are the timings and the panel counters (reads, hits,
//! evictions) each cache regime produces.
//!
//! Besides the human-readable report it writes `BENCH_OOC.json` — the
//! machine-readable trajectory future PRs regress against (docs/PERF.md).

use cggm::bench::write_bench_json;
use cggm::cggm::Dataset;
use cggm::coordinator;
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind};
use cggm::util::json::Json;
use std::time::Instant;

fn main() {
    let eng = NativeGemm::new(1);
    let (p, q, n) = (80usize, 80usize, 1000usize);
    let prob = datagen::chain::generate(p, q, n, 29);
    let dense_bytes = 8 * n * (p + q);
    let opts = SolveOptions {
        lam_l: 0.3,
        lam_t: 0.3,
        max_iter: 120,
        tol: 0.00001,
        ..Default::default()
    };

    // Stream the dataset out as sharded panels once; every disk leg reads
    // the same file.
    let path = std::env::temp_dir().join(format!("cggm_bench_ooc_{}.pan", std::process::id()));
    let t = Instant::now();
    coordinator::save_dataset_sharded(&prob.data, &path, 64).unwrap();
    let write_seconds = t.elapsed().as_secs_f64();
    let file_bytes = std::fs::metadata(&path).unwrap().len();
    println!(
        "# chain{p} out-of-core: {n} samples, dense {:.2} MB, panel file {:.2} MB written in {write_seconds:.3}s",
        dense_bytes as f64 / (1 << 20) as f64,
        file_bytes as f64 / (1 << 20) as f64,
    );

    // Resident baseline.
    let ctx = SolverContext::new(&prob.data, &opts, &eng);
    let t = Instant::now();
    let resident = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
    let resident_seconds = t.elapsed().as_secs_f64();
    assert!(resident.trace.converged);
    let f_resident = resident.trace.final_f().unwrap();
    println!(
        "#   resident      {:>3} iters {resident_seconds:.3}s (dataset {:.2} MB in core)",
        resident.trace.records.len(),
        dense_bytes as f64 / (1 << 20) as f64,
    );

    // Disk legs: each opens its own store so the counters are per-leg.
    let mut legs: Vec<Json> = Vec::new();
    let mut cold_evictions = 0u64;
    for (name, panel_rows, cache) in [
        ("disk_warm_cache", 64usize, 16usize << 20),
        // 8·16·1000 = 128 KB per panel: the 256 KB cache holds two of the
        // ten panels a sweep touches, so eviction churn is guaranteed
        // while single panels still admit (smaller and reads go transient,
        // which never counts as an eviction).
        ("disk_cold_cache", 16, 256 << 10),
    ] {
        let data = Dataset::open_disk(&path, panel_rows, cache).unwrap();
        let ctx = SolverContext::new(&data, &opts, &eng);
        let t = Instant::now();
        let got = solve_in_context(SolverKind::AltNewtonCd, &ctx, &opts, None).unwrap();
        let seconds = t.elapsed().as_secs_f64();
        let f = got.trace.final_f().unwrap();
        assert!(
            (f - f_resident).abs() <= 1e-6 * f_resident.abs().max(1.0),
            "{name}: disk-backed solve diverged from resident: {f} vs {f_resident}"
        );
        let stats = data.panel_stats().unwrap();
        assert!(stats.reads > 0, "{name}: solve never touched the panel layer");
        cold_evictions = stats.evictions;
        println!(
            "#   {name:<14}{:>3} iters {seconds:.3}s | cache {:>6.2} MB: {} reads, {} hits, {} misses, {} evictions",
            got.trace.records.len(),
            cache as f64 / (1 << 20) as f64,
            stats.reads,
            stats.hits,
            stats.misses,
            stats.evictions,
        );
        legs.push(Json::obj(vec![
            ("leg", Json::str(name)),
            ("panel_rows", Json::num(panel_rows as f64)),
            ("cache_bytes", Json::num(cache as f64)),
            ("seconds", Json::num(seconds)),
            ("iters", Json::num(got.trace.records.len() as f64)),
            ("panel_reads", Json::num(stats.reads as f64)),
            ("panel_hits", Json::num(stats.hits as f64)),
            ("panel_misses", Json::num(stats.misses as f64)),
            ("panel_evictions", Json::num(stats.evictions as f64)),
            ("panel_transient", Json::num(stats.transient as f64)),
            ("abs_delta_f", Json::num((f - f_resident).abs())),
        ]));
    }
    // The tight cache must actually have been tight, or the leg proves
    // nothing about degradation.
    assert!(cold_evictions > 0, "cold-cache leg never evicted a panel");

    let doc = Json::obj(vec![
        ("schema", Json::str("cggm-bench-ooc/v1")),
        (
            "problem",
            Json::obj(vec![
                ("workload", Json::str("chain")),
                ("p", Json::num(p as f64)),
                ("q", Json::num(q as f64)),
                ("n", Json::num(n as f64)),
            ]),
        ),
        ("dense_bytes", Json::num(dense_bytes as f64)),
        ("file_bytes", Json::num(file_bytes as f64)),
        ("write_seconds", Json::num(write_seconds)),
        ("resident_seconds", Json::num(resident_seconds)),
        ("resident_iters", Json::num(resident.trace.records.len() as f64)),
        ("legs", Json::arr(legs.into_iter())),
    ]);
    write_bench_json("OOC", &doc);
    let _ = std::fs::remove_file(&path);
}
