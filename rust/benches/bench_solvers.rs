//! End-to-end solver bench — Table 1 / Figure 1–2 in miniature: the three
//! methods on a chain and a clustered workload at fixed small sizes.

use cggm::bench::{Bench, BenchSet};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::solvers::{solve, SolveOptions, SolverKind};

fn main() {
    let mut set = BenchSet::new("solvers");
    let eng = NativeGemm::new(1);
    let chain = datagen::chain::generate(300, 300, 100, 5);
    let cluster = datagen::cluster_graph::generate(
        400,
        200,
        150,
        6,
        &datagen::cluster_graph::ClusterOptions {
            cluster_size: 50,
            hub_coeff: 3.0,
            ..Default::default()
        },
    );
    for (wname, prob, lam) in [("chain300", &chain, 1.5), ("cluster400x200", &cluster, 0.9)] {
        for kind in SolverKind::paper_three() {
            let opts = SolveOptions {
                lam_l: lam,
                lam_t: lam,
                max_iter: 60,
                ..Default::default()
            };
            set.push(
                Bench::new(format!("solve/{wname}/{}", kind.name()))
                    .warmup(1)
                    .iters(3)
                    .run(|| solve(kind, &prob.data, &opts, &eng).unwrap()),
            );
        }
    }
    set.finish();
}
