//! Streaming re-fit bench: what the incremental Gram update + warm re-fit
//! buys over a full rebuild and cold fit when k new samples slide a fixed
//! n-sample window.
//!
//! For each k the same slid window is fit twice: once by rank-k-correcting
//! the carried statistics and re-solving seeded from the live model, once
//! by recomputing every Gram block from the n samples and fitting from
//! scratch. Statistic work is counted in entry-updates — the incremental
//! path touches each of the `p² + q² + pq` entries once per appended and
//! once per evicted sample (`2k` passes) while a rebuild streams all `n`
//! samples — so the crossover is analytic: the update wins iff `2k < n`.
//!
//! Besides the human-readable report it writes `BENCH_REFIT.json` — the
//! machine-readable trajectory future PRs regress against (docs/PERF.md).

use cggm::bench::write_bench_json;
use cggm::cggm::{SampleBlock, WindowDelta};
use cggm::datagen;
use cggm::gemm::native::NativeGemm;
use cggm::linalg::dense::Mat;
use cggm::solvers::{solve_in_context, SolveOptions, SolverContext, SolverKind};
use cggm::util::json::Json;
use cggm::util::rng::Rng;
use std::time::Instant;

fn main() {
    let eng = NativeGemm::new(1);
    let (p, q, n) = (100usize, 100usize, 600usize);
    let prob = datagen::chain::generate(p, q, n, 13);
    let opts = SolveOptions {
        lam_l: 0.3,
        lam_t: 0.3,
        max_iter: 120,
        tol: 0.00001,
        ..Default::default()
    };

    // The model that is "live" when new samples start arriving.
    let base_ctx = SolverContext::new(&prob.data, &opts, &eng);
    let base = solve_in_context(SolverKind::AltNewtonCd, &base_ctx, &opts, None).unwrap();
    assert!(base.trace.converged);
    drop(base_ctx);
    let entries = (p * p + q * q + p * q) as f64;
    println!(
        "# chain{p} streaming refit, {n}-sample window: warm+incremental vs cold+rebuild"
    );

    let mut legs: Vec<Json> = Vec::new();
    for k in [1usize, 16, 256] {
        // The identical slid window feeds both legs: k new samples in, the
        // k oldest out.
        let mut data = prob.data.clone();
        let mut rng = Rng::new(100 + k as u64);
        let mut delta = WindowDelta::new(data.n());
        let xa = Mat::from_fn(p, k, |_, _| rng.normal());
        let ya = Mat::from_fn(q, k, |_, _| rng.normal());
        data.append_samples(&xa, &ya).unwrap();
        delta.record_append(SampleBlock::new(xa, ya));
        delta.record_evict(data.evict_oldest(k).unwrap());

        // Warm leg: carry statistics from a context over the old window,
        // rank-k correct them, re-solve seeded from the live model.
        let donor = SolverContext::new(&prob.data, &opts, &eng);
        donor.syy().unwrap();
        donor.sxx().unwrap();
        donor.sxy().unwrap();
        let mut warm_ctx = SolverContext::with_carry(&data, &opts, &eng, donor.into_carry());
        let t = Instant::now();
        warm_ctx.update_stats(&delta).unwrap();
        let update_seconds = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let warm =
            solve_in_context(SolverKind::AltNewtonCd, &warm_ctx, &opts, Some(&base.model))
                .unwrap();
        let warm_seconds = t.elapsed().as_secs_f64();
        assert!(warm.trace.warm_started);

        // Cold leg: every Gram block rebuilt from the n-sample window, fit
        // from scratch.
        let cold_ctx = SolverContext::new(&data, &opts, &eng);
        let t = Instant::now();
        cold_ctx.syy().unwrap();
        cold_ctx.sxx().unwrap();
        cold_ctx.sxy().unwrap();
        let rebuild_seconds = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let cold = solve_in_context(SolverKind::AltNewtonCd, &cold_ctx, &opts, None).unwrap();
        let cold_seconds = t.elapsed().as_secs_f64();

        // Same optimum either way — the update is exact, not approximate.
        let (fw, fc) = (warm.trace.final_f().unwrap(), cold.trace.final_f().unwrap());
        assert!(
            (fw - fc).abs() <= 1e-6 * fc.abs().max(1.0),
            "k={k}: warm refit diverged from cold fit: {fw} vs {fc}"
        );

        let inc_work = 2.0 * k as f64 * entries;
        let rebuild_work = n as f64 * entries;
        let (wi, ci) = (warm.trace.records.len(), cold.trace.records.len());
        println!(
            "#   k={k:<4} stats {:>8.1}k entry-updates in {:.4}s vs rebuild {:>9.1}k in {:.4}s \
             | solve {wi:>3} warm iters {warm_seconds:.3}s vs {ci:>3} cold {cold_seconds:.3}s",
            inc_work / 1e3,
            update_seconds,
            rebuild_work / 1e3,
            rebuild_seconds,
        );
        // Acceptance: incremental statistics work strictly below a full
        // rebuild, and the warm start saves solver iterations.
        assert!(
            inc_work < rebuild_work,
            "k={k}: incremental stat work {inc_work} must undercut rebuild {rebuild_work}"
        );
        assert!(
            wi <= ci,
            "k={k}: warm refit took more iterations ({wi}) than the cold fit ({ci})"
        );

        legs.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("update_seconds", Json::num(update_seconds)),
            ("rebuild_seconds", Json::num(rebuild_seconds)),
            ("inc_entry_updates", Json::num(inc_work)),
            ("rebuild_entry_updates", Json::num(rebuild_work)),
            ("warm_iters", Json::num(wi as f64)),
            ("cold_iters", Json::num(ci as f64)),
            ("warm_seconds", Json::num(warm_seconds)),
            ("cold_seconds", Json::num(cold_seconds)),
            ("stat_updates", Json::num(warm_ctx.stat_updates() as f64)),
            ("abs_delta_f", Json::num((fw - fc).abs())),
        ]));
    }

    let doc = Json::obj(vec![
        ("schema", Json::str("cggm-bench-refit/v1")),
        (
            "problem",
            Json::obj(vec![
                ("workload", Json::str("chain")),
                ("p", Json::num(p as f64)),
                ("q", Json::num(q as f64)),
                ("n", Json::num(n as f64)),
            ]),
        ),
        ("base_iters", Json::num(base.trace.records.len() as f64)),
        ("legs", Json::arr(legs.into_iter())),
    ]);
    write_bench_json("REFIT", &doc);
}
